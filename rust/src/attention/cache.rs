//! ct-contract: bit-exact
//!
//! The incremental-decode subsystem: a per-session KV-panel store
//! ([`KvCache`]) and a [`CachingBackend`] that wraps any
//! [`AttentionBackend`] with cross-request KV caching.
//!
//! ## The decode problem
//!
//! Autoregressive traffic submits the *same growing history* step after
//! step: a prefill of `p` rows, then steps that each add a few rows and
//! need attention for only those new rows — over **all** rows seen so
//! far.  Without a cache every step is a full O(N²) recompute.  With
//! one, the step appends its new K/V rows to the session's cached
//! panels and solves only the incremental query span, which for the
//! row-independent families is O(m·N).
//!
//! ## The correctness contract
//!
//! > A cached incremental step is **bit-for-bit identical** to
//! > recomputing the full unpadded history through the wrapped backend
//! > with the session's PRNG streams
//! > (`slice_stream(session_seed(seed, sid), head)`), restricted to the
//! > span rows.
//!
//! Nothing about the cache is approximate by default.  The mechanisms,
//! per family:
//!
//! - **full / shared-full / oracle-top** — per-query-row independence:
//!   the kernels' `query_span` path streams only the new rows against
//!   every cached key (shared-full's keys are the cached *query*
//!   history, which is why the store keeps Q panels too).
//! - **clustered** — the kernel's span path re-clusters the full query
//!   history (same RNG draws as a full solve) and runs the centroid
//!   pass only for the clusters the span touches.
//! - **improved / lsh** — rows couple through shared state, so the
//!   exact span is a full recompute with span extraction.
//! - **linear (causal)** — the O(1)-state family: instead of panels the
//!   session entry holds per-head [`RecurrentState`] accumulators
//!   (`S: Dk×Dv`, `z: Dk`), everything a causal row needs to know about
//!   the keys below it.  A hit absorbs the step's new K/V rows into the
//!   accumulator and emits the span rows directly — O(m·D²) per step,
//!   **independent of history length** — replaying exactly the
//!   elementary accumulation order of the full causal recompute, so the
//!   step is bit-identical to it.  Bidirectional linear sessions use
//!   the ordinary panel path (every row attends future keys, so the
//!   prefix state alone cannot serve them).
//! - Any **miss** (no entry, evicted entry, stale generation, desynced
//!   length, zero-capacity store, panel/recurrent kind mismatch) falls
//!   back to the wrapped backend on the full descriptor and repopulates
//!   the cache — identical by construction.
//!
//! ## Frozen-model reuse (the growth threshold)
//!
//! Re-clustering every step costs O(N) hashing + Lloyd work per step.
//! With `KvCacheOptions::growth > 1.0` the clustered families freeze
//! their clustering model (LSH projections, Hamming centroids, real
//! centroids) at the last re-cluster and, while
//! `len <= growth · clustered_len`, assign only the *new* queries to
//! the frozen centroids and attend through the affected clusters —
//! O(m·C + |affected|·N·D) per step.  Reused steps are deterministic
//! (bit-identical for any worker count) but **approximate** relative to
//! a fresh clustering, in exactly the way clustered attention is
//! approximate relative to full attention; the step that crosses the
//! threshold re-clusters and is exact again.  The default
//! (`growth = 1.0`) re-clusters every step: exactness everywhere.
//!
//! Capacity is accounted in cached *sequence rows* (`Σ session len`);
//! eviction is LRU by last touch.  A recurrent entry's size never
//! grows, so it charges a constant row-equivalent
//! ([`recurrent_rows_equiv`]: its float count expressed in panel-row
//! units) and competes in the same LRU order as the panel entries.  A
//! zero-capacity store caches nothing, so every step recomputes — the
//! always-miss degenerate that the fallback contract keeps
//! bit-identical.
//!
//! ## Quantized panels (opt-in, tolerance-gated)
//!
//! With [`KvCacheOptions::quant`] set to a [`CacheQuant`] i8 mode, the
//! store keeps panels as symmetric-i8 codes ([`crate::tensor::quant`])
//! instead of f32 rows and charges them their true byte cost —
//! [`quant_rows_equiv`]`(len) = ceil(len / 4)` rows, i.e. ≥4× more
//! live sessions in the same budget.  A hit dequantizes the panels
//! into plain [`Matrix`] scratch before the solve, so no kernel
//! family changes its math; the miss/prefill path still computes from
//! the caller's raw f32 inputs and stays bit-exact.  Because the
//! quantize→dequantize round trip is lossy, *post-prefill hit steps*
//! are the repo's first sanctioned departure from the bit-identity
//! contract: they are gated by the numeric tolerance policy
//! (`oracle/policy.rs`, `output_bits: {abs_tol, rel_tol}`) instead,
//! and stay bit-exact whenever `quant` is `Off` (the default).
//! Recurrent (linear-causal) entries are never quantized — their
//! charge is already O(1) in history length.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::clustering::{assign_nearest, hamming_kmeans_model_ctx, Lsh};
use crate::exec::ExecCtx;
use crate::prng::{session_seed, slice_stream};
use crate::tensor::batch::BatchMatrix;
use crate::tensor::quant::QuantPanel;
use crate::tensor::{axpy, dot, softmax_inplace, topk_indices, Matrix};

use super::backend::{AttentionBackend, NativeBackend};
use super::clustered::{centroids, clustered_span_attention_ctx};
use super::improved::improved_clustered_attention_ctx;
use super::linear::RecurrentState;
use super::problem::{AttnBatch, AttnProblem, CacheRef, SessionRef};
use super::{kernel_for, AttentionKernel, Variant};

/// K/V panel storage mode: exact f32 (the default, bit-identical) or
/// symmetric-i8 quantized panels (tolerance-gated — see
/// [`crate::tensor::quant`] and the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CacheQuant {
    /// Full-precision f32 panels: cached decode is bit-identical to
    /// the full recompute.
    #[default]
    Off,
    /// i8 codes under one scale per (session, head) panel, frozen at
    /// the session's populate; later appends reuse it and saturate.
    I8PerHead,
    /// i8 codes with a fresh absmax scale per appended segment.
    I8PerPanel,
}

impl CacheQuant {
    /// Parse the CLI / wire spelling: `off` | `i8-head` | `i8-panel`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "i8-head" => Some(Self::I8PerHead),
            "i8-panel" => Some(Self::I8PerPanel),
            _ => None,
        }
    }

    /// The stable CLI / wire spelling ([`Self::parse`] round-trips
    /// it).
    pub fn name(self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::I8PerHead => "i8-head",
            Self::I8PerPanel => "i8-panel",
        }
    }
}

/// KV-cache sizing, re-cluster and storage-precision policy.
#[derive(Debug, Clone, Copy)]
pub struct KvCacheOptions {
    /// Maximum cached sequence rows summed over sessions (`Σ len`).
    /// `0` caches nothing (every step misses and recomputes).
    pub capacity_rows: usize,
    /// Clustered-family re-cluster threshold: reuse the frozen
    /// clustering while `len <= growth · clustered_len`.  `1.0` (the
    /// default) re-clusters every step — exact everywhere; values
    /// above 1.0 trade exactness between re-clusters for O(m) steps.
    pub growth: f64,
    /// Panel storage precision.  [`CacheQuant::Off`] (the default)
    /// keeps the bit-identity contract; the i8 modes store 4× denser
    /// panels and gate hit outputs by the declared numeric tolerance.
    pub quant: CacheQuant,
}

impl Default for KvCacheOptions {
    fn default() -> Self {
        Self {
            capacity_rows: usize::MAX,
            growth: 1.0,
            quant: CacheQuant::Off,
        }
    }
}

/// Cache traffic counters (atomic; shared across buckets).
#[derive(Debug, Default)]
pub struct CacheCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    /// Sessions dropped to make room (LRU) or because they outgrew the
    /// capacity.
    pub evictions: AtomicU64,
    /// New rows appended on hits.
    pub appended_rows: AtomicU64,
    /// Prefix rows *not* recomputed thanks to hits (`Σ span_start`).
    pub reused_rows: AtomicU64,
    /// Rows recomputed on misses (`Σ len`).
    pub recomputed_rows: AtomicU64,
}

impl CacheCounters {
    /// Hits over lookups, in [0, 1] (0 when no lookup happened).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }
}

/// Frozen clustering model of one (session, head) slice — everything a
/// reused step needs to assign new queries and attend through their
/// clusters without re-running LSH + Lloyd on the history.
#[derive(Debug, Clone)]
pub(crate) struct HeadModel {
    bits: usize,
    /// LSH projection directions (bits × Dk) of the last re-cluster.
    proj: Matrix,
    /// Packed Hamming centroids (C × words_per_code) — new queries
    /// assign against these.
    cent_codes: Vec<u64>,
    /// Real-space centroids (C × Dk) — the frozen attention queries.
    cent_real: Matrix,
}

/// One head's cached panel: immutable, Arc-shared, append-only row
/// segments (one segment per populate/step).  Rows never mutate after
/// they land in a segment, so a hit "takes" the whole history with
/// `clone()` — O(#segments) pointer bumps, no row data touched — and
/// the store lock is held only for the lookup + append.  The contiguous
/// matrix a solve needs is assembled lock-free by [`Panel::to_matrix`];
/// eviction can race that assembly safely because the Arcs keep every
/// segment alive for as long as any snapshot does.
#[derive(Debug, Clone)]
pub(crate) struct Panel {
    rows: usize,
    cols: usize,
    segs: Vec<Arc<Vec<f32>>>,
}

impl Panel {
    /// Seed a panel from a freshly recomputed history (no copy — the
    /// matrix's storage becomes the first segment).
    fn from_matrix(m: Matrix) -> Self {
        Self { rows: m.rows, cols: m.cols, segs: vec![Arc::new(m.data)] }
    }

    /// Append a step's new rows as one fresh segment (copies only the
    /// new rows; the history segments are untouched and stay shared).
    fn append(&mut self, m: &Matrix) {
        debug_assert_eq!(m.cols, self.cols, "panel column mismatch");
        self.rows += m.rows;
        self.segs.push(Arc::new(m.data.clone()));
    }

    /// Contiguous copy of the whole panel — called *outside* the store
    /// lock, so the per-step O(len·D) assembly never serializes
    /// concurrent bucket steps the way the old under-lock clone did.
    pub(crate) fn to_matrix(&self) -> Matrix {
        if let [seg] = self.segs.as_slice() {
            return Matrix { rows: self.rows, cols: self.cols,
                            data: seg.as_ref().clone() };
        }
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for seg in &self.segs {
            data.extend_from_slice(seg);
        }
        debug_assert_eq!(data.len(), self.rows * self.cols);
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

/// One head's cached panel in whichever precision the store runs:
/// exact f32 segments ([`Panel`]) or symmetric-i8 segments
/// ([`QuantPanel`]).  Both are Arc-shared append-only segment lists,
/// so hit snapshots stay O(#segments) pointer clones either way; the
/// only difference is that [`StoredPanel::to_matrix`] dequantizes the
/// i8 codes into the plain f32 scratch the solve runs over.
#[derive(Debug, Clone)]
pub(crate) enum StoredPanel {
    Exact(Panel),
    Quant(QuantPanel),
}

impl StoredPanel {
    fn from_matrix(m: Matrix, quant: CacheQuant) -> Self {
        match quant {
            CacheQuant::Off => Self::Exact(Panel::from_matrix(m)),
            CacheQuant::I8PerHead => {
                Self::Quant(QuantPanel::from_matrix(&m, true))
            }
            CacheQuant::I8PerPanel => {
                Self::Quant(QuantPanel::from_matrix(&m, false))
            }
        }
    }

    fn append(&mut self, m: &Matrix) {
        match self {
            Self::Exact(p) => p.append(m),
            Self::Quant(p) => p.append(m),
        }
    }

    /// Contiguous f32 view of the whole panel (dequantized when the
    /// store is an i8 mode) — the matrix kernel code actually sees,
    /// assembled outside the store lock.
    pub(crate) fn to_matrix(&self) -> Matrix {
        match self {
            Self::Exact(p) => p.to_matrix(),
            Self::Quant(p) => p.to_matrix(),
        }
    }

    fn quantized(&self) -> bool {
        matches!(self, Self::Quant(_))
    }
}

/// One session's cached state: per-head appended Q/K/V panels (the Q
/// panel is the key history of shared-QK families and the re-cluster
/// input of the clustered ones) plus the optional frozen clustering —
/// or, for linear-family causal sessions, per-head [`RecurrentState`]
/// accumulators instead of panels (the panels stay empty).
struct SessionEntry {
    generation: u64,
    heads: usize,
    dk: usize,
    dv: usize,
    /// Cached history rows (every panel has exactly this many rows;
    /// for a recurrent entry, the rows absorbed so far).
    len: usize,
    last_used: u64,
    q: Vec<StoredPanel>,
    k: Vec<StoredPanel>,
    v: Vec<StoredPanel>,
    model: Option<Vec<HeadModel>>,
    /// History length at the last re-cluster (0 = never clustered).
    clustered_len: usize,
    /// Per-head `(S, z)` accumulators — `Some` makes this a recurrent
    /// entry (linear family, causal); panel and recurrent kinds never
    /// serve each other's lookups.
    recurrent: Option<Vec<RecurrentState>>,
}

impl SessionEntry {
    /// Capacity charge in cached sequence rows: exact panel entries
    /// charge their length, quantized ones their true byte cost
    /// ([`quant_rows_equiv`]), recurrent entries the constant
    /// row-equivalent of their accumulator floats.
    fn charged_rows(&self) -> usize {
        if self.recurrent.is_some() {
            recurrent_rows_equiv(self.dk, self.dv)
        } else if self.q.first().is_some_and(StoredPanel::quantized) {
            quant_rows_equiv(self.len)
        } else {
            self.len
        }
    }
}

/// A quantized panel entry's capacity charge: i8 codes are a quarter
/// of the f32 row bytes (the per-segment f32 scales amortize to
/// nothing), so `len` history rows charge `ceil(len / 4)` budget rows
/// — the ≥4×-sessions-per-GB density the quantized mode exists for.
pub(crate) fn quant_rows_equiv(len: usize) -> usize {
    len.div_ceil(4)
}

/// A recurrent entry's capacity charge: its per-head float count
/// (`Dk·Dv + Dk`) expressed in panel sequence-row units (`2·Dk + Dv`
/// floats per row per head — the head counts cancel), at least 1 so a
/// live accumulator is never free.  Constant in history length, which
/// is the whole point of the recurrent family.
pub(crate) fn recurrent_rows_equiv(dk: usize, dv: usize) -> usize {
    (dk * dv + dk).div_ceil(2 * dk + dv).max(1)
}

struct Store {
    sessions: BTreeMap<u64, SessionEntry>,
    used_rows: usize,
    clock: u64,
}

/// Everything a hit hands the backend: Arc-shared snapshots of the full
/// panels (pointer clones only — no row data is copied under the store
/// lock) and the frozen model when this step may reuse it.  The backend
/// materializes contiguous matrices from the snapshots lock-free.
pub(crate) struct HitData {
    pub q: Vec<StoredPanel>,
    pub k: Vec<StoredPanel>,
    pub v: Vec<StoredPanel>,
    pub model: Option<Vec<HeadModel>>,
    pub reuse: bool,
}

/// Per-session, per-head appended K/V (and Q) panel store with
/// capacity + LRU-eviction accounting.  See the module docs for the
/// correctness contract; [`CachingBackend`] is the consumer.
pub struct KvCache {
    opts: KvCacheOptions,
    store: Mutex<Store>,
    counters: CacheCounters,
}

impl KvCache {
    pub fn new(opts: KvCacheOptions) -> Self {
        Self {
            opts,
            store: Mutex::new(Store {
                sessions: BTreeMap::new(),
                used_rows: 0,
                clock: 0,
            }),
            counters: CacheCounters::default(),
        }
    }

    /// Unbounded store with the exact (re-cluster-every-step) policy.
    pub fn unbounded() -> Self {
        Self::new(KvCacheOptions::default())
    }

    /// Bounded store with the exact policy.
    pub fn with_capacity(capacity_rows: usize) -> Self {
        Self::new(KvCacheOptions { capacity_rows,
                                   ..KvCacheOptions::default() })
    }

    pub fn options(&self) -> KvCacheOptions {
        self.opts
    }

    /// Panel storage precision ([`CacheQuant::Off`] = exact f32, the
    /// default).
    pub fn quant(&self) -> CacheQuant {
        self.opts.quant
    }

    pub fn counters(&self) -> &CacheCounters {
        &self.counters
    }

    /// Cached sequence rows currently held (`Σ session len`).
    pub fn used_rows(&self) -> usize {
        self.store.lock().unwrap().used_rows
    }

    /// Cached length of a session — `None` unless the entry exists
    /// *and* the generation matches (a stale handle sees nothing).
    pub fn session_len(&self, r: CacheRef) -> Option<usize> {
        let store = self.store.lock().unwrap();
        store
            .sessions
            .get(&r.session)
            .filter(|e| e.generation == r.generation)
            .map(|e| e.len)
    }

    /// Drop a session's cached state (e.g. the gateway ended it).
    pub fn invalidate(&self, session: u64) {
        let mut store = self.store.lock().unwrap();
        if let Some(e) = store.sessions.remove(&session) {
            store.used_rows -= e.charged_rows();
        }
    }

    /// Evict LRU sessions (preferring ones other than `keep`) until the
    /// store fits its capacity.  May evict `keep` itself as a last
    /// resort — callers clone what they need before calling this.
    fn evict_until_fits(&self, store: &mut Store, keep: u64) {
        while store.used_rows > self.opts.capacity_rows {
            let victim = store
                .sessions
                .iter()
                .filter(|(id, _)| **id != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(id, _)| *id)
                .or_else(|| store.sessions.contains_key(&keep)
                            .then_some(keep));
            let Some(id) = victim else { break };
            let e = store.sessions.remove(&id).unwrap();
            store.used_rows -= e.charged_rows();
            self.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One decode step's cache transaction: on a usable entry (same
    /// generation, cached length == `span_start`, same geometry) append
    /// the new rows and return the full panels; anything else is a miss
    /// (stale entries are dropped so they can never alias).
    ///
    /// The panels are append-only Arc-shared segment lists, so the hit
    /// snapshot is O(#segments) pointer clones: the lock is held only
    /// for the lookup and the append of the new rows (one fresh
    /// segment per head), never for an O(len·D) history memcpy.  The
    /// contiguous view a solve needs is assembled lock-free from the
    /// snapshot ([`Panel::to_matrix`]), which is what stops concurrent
    /// bucket steps from serializing on the store lock.
    pub(crate) fn step(&self, r: CacheRef, heads: usize, dk: usize,
                       dv: usize, span_start: usize, new_q: &[Matrix],
                       new_k: &[Matrix], new_v: &[Matrix])
                       -> Option<HitData> {
        if self.opts.capacity_rows == 0 || span_start == 0 {
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut store = self.store.lock().unwrap();
        store.clock += 1;
        let tick = store.clock;
        let usable = store.sessions.get(&r.session).is_some_and(|e| {
            e.generation == r.generation
                && e.len == span_start
                && (e.heads, e.dk, e.dv) == (heads, dk, dv)
                && e.recurrent.is_none()
        });
        if !usable {
            // a mismatched entry must never alias: drop it now, the
            // recompute path repopulates under the caller's handle
            if let Some(e) = store.sessions.remove(&r.session) {
                store.used_rows -= e.charged_rows();
            }
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let m = new_q[0].rows;
        let e = store.sessions.get_mut(&r.session).unwrap();
        // charge by delta so quantized entries (whose charge is
        // ceil(len/4), not len) stay consistent under appends
        let charge_before = e.charged_rows();
        for h in 0..heads {
            e.q[h].append(&new_q[h]);
            e.k[h].append(&new_k[h]);
            e.v[h].append(&new_v[h]);
        }
        e.len += m;
        e.last_used = tick;
        let reuse = e.model.is_some()
            && e.len as f64 <= self.opts.growth * e.clustered_len as f64;
        let hit = HitData {
            q: e.q.clone(),
            k: e.k.clone(),
            v: e.v.clone(),
            model: if reuse { e.model.clone() } else { None },
            reuse,
        };
        store.used_rows += e.charged_rows() - charge_before;
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
        self.counters
            .appended_rows
            .fetch_add(m as u64, Ordering::Relaxed);
        self.counters
            .reused_rows
            .fetch_add(span_start as u64, Ordering::Relaxed);
        self.evict_until_fits(&mut store, r.session);
        Some(hit)
    }

    /// Store a freshly recomputed session history (the miss path).
    pub(crate) fn populate(&self, r: CacheRef, heads: usize, dk: usize,
                           dv: usize, q: Vec<Matrix>, k: Vec<Matrix>,
                           v: Vec<Matrix>) {
        if self.opts.capacity_rows == 0 {
            return;
        }
        let len = q[0].rows;
        let quant = self.opts.quant;
        let charge = match quant {
            CacheQuant::Off => len,
            _ => quant_rows_equiv(len),
        };
        // seed (and, in the i8 modes, encode — O(len·D)) the panels
        // before the store lock, like the recurrent absorption path
        let panels = |ms: Vec<Matrix>| {
            ms.into_iter()
                .map(|m| StoredPanel::from_matrix(m, quant))
                .collect::<Vec<StoredPanel>>()
        };
        let (qp, kp, vp) = (panels(q), panels(k), panels(v));
        let mut store = self.store.lock().unwrap();
        store.clock += 1;
        let tick = store.clock;
        if let Some(e) = store.sessions.remove(&r.session) {
            store.used_rows -= e.charged_rows();
        }
        if charge > self.opts.capacity_rows {
            // the session alone exceeds the store: cannot cache it
            self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            return;
        }
        store.used_rows += charge;
        store.sessions.insert(r.session, SessionEntry {
            generation: r.generation,
            heads,
            dk,
            dv,
            len,
            last_used: tick,
            q: qp,
            k: kp,
            v: vp,
            model: None,
            clustered_len: 0,
            recurrent: None,
        });
        self.evict_until_fits(&mut store, r.session);
    }

    /// One *recurrent* decode step's cache transaction (linear family,
    /// causal): on a usable entry (same generation, absorbed length ==
    /// `span_start`, same geometry, recurrent kind) return a snapshot of
    /// the per-head accumulators *as of the span start*, then absorb the
    /// step's new K/V rows into the entry — O(m·D²) under the lock,
    /// independent of history length, which is the O(1)-state contract.
    /// Anything else — a panel entry included — is a miss and drops the
    /// entry so it can never alias;
    /// [`CachingBackend`] repopulates via [`Self::populate_recurrent`].
    pub(crate) fn step_recurrent(&self, r: CacheRef, heads: usize,
                                 dk: usize, dv: usize, span_start: usize,
                                 new_k: &[Matrix], new_v: &[Matrix])
                                 -> Option<Vec<RecurrentState>> {
        if self.opts.capacity_rows == 0 || span_start == 0 {
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut store = self.store.lock().unwrap();
        store.clock += 1;
        let tick = store.clock;
        let usable = store.sessions.get(&r.session).is_some_and(|e| {
            e.generation == r.generation
                && e.len == span_start
                && (e.heads, e.dk, e.dv) == (heads, dk, dv)
                && e.recurrent.is_some()
        });
        if !usable {
            if let Some(e) = store.sessions.remove(&r.session) {
                store.used_rows -= e.charged_rows();
            }
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let m = new_k[0].rows;
        let e = store.sessions.get_mut(&r.session).unwrap();
        let prior = e.recurrent.clone().unwrap();
        let states = e.recurrent.as_mut().unwrap();
        for h in 0..heads {
            for j in 0..m {
                states[h].absorb(new_k[h].row(j), new_v[h].row(j));
            }
        }
        e.len += m;
        e.last_used = tick;
        // the accumulator's charge is constant — used_rows is unchanged
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
        self.counters
            .appended_rows
            .fetch_add(m as u64, Ordering::Relaxed);
        self.counters
            .reused_rows
            .fetch_add(span_start as u64, Ordering::Relaxed);
        Some(prior)
    }

    /// Store a freshly recomputed recurrent session (the linear causal
    /// miss path): fresh per-head accumulators absorb the full K/V
    /// history in ascending row order — the pinned elementary order the
    /// bit-identity contract is built on.  The absorption runs before
    /// the store lock is taken.
    pub(crate) fn populate_recurrent(&self, r: CacheRef, heads: usize,
                                     dk: usize, dv: usize, k: &[Matrix],
                                     v: &[Matrix]) {
        if self.opts.capacity_rows == 0 {
            return;
        }
        let len = k[0].rows;
        let charge = recurrent_rows_equiv(dk, dv);
        let states: Vec<RecurrentState> = (0..heads)
            .map(|h| {
                let mut st = RecurrentState::new(dk, dv);
                for j in 0..len {
                    st.absorb(k[h].row(j), v[h].row(j));
                }
                st
            })
            .collect();
        let mut store = self.store.lock().unwrap();
        store.clock += 1;
        let tick = store.clock;
        if let Some(e) = store.sessions.remove(&r.session) {
            store.used_rows -= e.charged_rows();
        }
        if charge > self.opts.capacity_rows {
            // the accumulator alone exceeds the store: cannot cache it
            self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            return;
        }
        store.used_rows += charge;
        store.sessions.insert(r.session, SessionEntry {
            generation: r.generation,
            heads,
            dk,
            dv,
            len,
            last_used: tick,
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            model: None,
            clustered_len: 0,
            recurrent: Some(states),
        });
        self.evict_until_fits(&mut store, r.session);
    }

    /// Attach a freshly computed clustering model (the re-cluster
    /// path).  Silently dropped if the entry vanished in between.
    pub(crate) fn store_model(&self, r: CacheRef, models: Vec<HeadModel>,
                              clustered_len: usize) {
        let mut store = self.store.lock().unwrap();
        if let Some(e) = store.sessions.get_mut(&r.session) {
            if e.generation == r.generation && e.len == clustered_len {
                e.model = Some(models);
                e.clustered_len = clustered_len;
            }
        }
    }
}

/// What happened to one sequence of a [`CachingBackend`] execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqOutcome {
    /// Not a session sequence — rode the wrapped backend unchanged.
    Bypass,
    /// Cached prefix found: the cache transaction appended only the
    /// new rows.
    Hit {
        /// Prefix rows the cache held (`span_start`).
        reused_rows: usize,
        /// Output rows the backend actually materialized for this
        /// step: the span (`len - span_start`) for the genuinely
        /// incremental families, the full history for the
        /// recompute-with-extraction ones (lsh; improved on a
        /// re-cluster step) — the honest number behind any
        /// compute-saved metric.
        computed_rows: usize,
        /// Clustered families: whether this step re-clustered (`true`,
        /// exact) or reused the frozen model (`false`).
        reclustered: bool,
    },
    /// No usable cache entry: full recompute + repopulation.
    Miss {
        /// History rows the fallback recomputed.
        recomputed_rows: usize,
    },
}

/// How the backend solves a hit for this kernel family.
enum FamilyPlan {
    /// The kernel's own `query_span` path is exact.  `full_recompute`
    /// is `false` for the genuinely incremental families (full,
    /// shared-full, oracle-top: O(m·N) per step) and `true` for the
    /// lsh families, whose span is a full solve with extraction — the
    /// honest accounting behind [`SeqOutcome::Hit::computed_rows`].
    Span { full_recompute: bool },
    /// Clustered families: the backend owns the clustering so it can
    /// freeze and reuse it across steps.
    ClusterModel {
        clusters: usize,
        bits: usize,
        iters: usize,
        /// `Some` for improved clustered (its top-k refinement).
        topk: Option<usize>,
    },
    /// Linear family: *causal* sessions store per-head
    /// [`RecurrentState`] accumulators instead of panels and step in
    /// O(m·D²) regardless of history length; bidirectional sessions
    /// fall through to the panel span path (the kernel's span solve is
    /// genuinely incremental there too).
    Recurrent,
}

fn plan_for(variant: &Variant) -> FamilyPlan {
    match *variant {
        Variant::Clustered { clusters, bits, iters } => {
            FamilyPlan::ClusterModel { clusters, bits, iters, topk: None }
        }
        Variant::ImprovedClustered { clusters, bits, iters, topk } => {
            FamilyPlan::ClusterModel { clusters, bits, iters,
                                       topk: Some(topk) }
        }
        Variant::Lsh { .. } | Variant::LshHam { .. } => {
            FamilyPlan::Span { full_recompute: true }
        }
        Variant::Linear => FamilyPlan::Recurrent,
        _ => FamilyPlan::Span { full_recompute: false },
    }
}

/// Owned copy of rows `r0..r1` of batch slice `s`.
fn seq_rows(t: &BatchMatrix, s: usize, r0: usize, r1: usize) -> Matrix {
    let vw = t.view(s);
    Matrix {
        rows: r1 - r0,
        cols: t.cols,
        data: vw.data[r0 * t.cols..r1 * t.cols].to_vec(),
    }
}

/// Gather a subset of sequences into a dense sub-batch (slice order).
fn gather(t: &BatchMatrix, idx: &[usize]) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(idx.len(), t.heads, t.rows, t.cols);
    for (pos, &b) in idx.iter().enumerate() {
        for h in 0..t.heads {
            out.slice_mut(pos * t.heads + h)
                .copy_from_slice(t.view(b * t.heads + h).data);
        }
    }
    out
}

/// Cross-request KV caching over any [`AttentionBackend`].
///
/// Sequences without a [`SessionRef`] ride the wrapped backend as one
/// sub-batch (an all-plain flush is bit-identical to the uncached
/// path).  Session sequences resolve through the [`KvCache`]: hits
/// solve only the incremental span against the cached panels, misses
/// recompute the full history through the wrapped backend and
/// repopulate.  Either way the span rows equal the full unpadded
/// recompute bit-for-bit (module docs).
///
/// [`SessionRef`]: super::problem::SessionRef
///
/// ```
/// use std::sync::Arc;
/// use clustered_transformers::attention::{AttentionBackend,
///                                         CachingBackend, KvCache};
///
/// let cache = Arc::new(KvCache::unbounded());
/// let backend = CachingBackend::native("full", cache).unwrap();
/// assert_eq!(backend.backend_name(), "cached:native:full");
/// ```
pub struct CachingBackend {
    inner: Box<dyn AttentionBackend>,
    kernel: Box<dyn AttentionKernel>,
    plan: FamilyPlan,
    cache: Arc<KvCache>,
}

impl CachingBackend {
    /// Wrap `inner` with caching for the named kernel family (the name
    /// tells the backend which incremental strategy is exact).
    pub fn wrap(inner: Box<dyn AttentionBackend>, kernel: &str,
                cache: Arc<KvCache>) -> Option<Self> {
        let variant = Variant::parse(kernel)?;
        Some(Self {
            inner,
            kernel: kernel_for(&variant),
            plan: plan_for(&variant),
            cache,
        })
    }

    /// Caching over the in-tree native backend.
    pub fn native(kernel: &str, cache: Arc<KvCache>) -> Option<Self> {
        let inner = NativeBackend::by_name(kernel)?;
        Self::wrap(Box::new(inner), kernel, cache)
    }

    pub fn cache(&self) -> &Arc<KvCache> {
        &self.cache
    }

    /// Execute one descriptor and report, per sequence, how the cache
    /// treated it.  [`AttentionBackend::execute`] is this minus the
    /// report.
    ///
    /// Session sequences leave rows `0..span_start` of their output
    /// slices zero (only the span is contractual — and computed);
    /// plain and miss sequences carry every valid row as usual.
    pub fn execute_with_report(&self, batch: &AttnBatch<'_>,
                               ctx: &ExecCtx)
                               -> (BatchMatrix, Vec<SeqOutcome>) {
        batch.validate();
        let (q, k, v) = (batch.q, batch.k, batch.v);
        let (bsz, heads) = (q.batch, q.heads);
        let (dk, dv) = (q.cols, v.cols);
        let Some(sessions) = batch.sessions else {
            return (self.inner.execute(batch, ctx),
                    vec![SeqOutcome::Bypass; bsz]);
        };
        let mut out = BatchMatrix::zeros(bsz, heads, q.rows, dv);
        let mut outcomes = vec![SeqOutcome::Bypass; bsz];

        // ordinary sequences: one sub-batch through the wrapped
        // backend; sub-batch position keys their PRNG streams, so an
        // all-plain flush is bit-identical to the uncached path
        let plain: Vec<usize> =
            (0..bsz).filter(|&b| sessions[b].is_none()).collect();
        if !plain.is_empty() {
            let (sq, sk, sv) =
                (gather(q, &plain), gather(k, &plain), gather(v, &plain));
            let lens: Option<Vec<usize>> = batch
                .lens
                .map(|ls| plain.iter().map(|&b| ls[b]).collect());
            let mut sub = AttnBatch::new(&sq, &sk, &sv, batch.seed)
                .with_causal(batch.causal);
            if let Some(ls) = lens.as_deref() {
                sub = sub.with_lens(ls);
            }
            let o = self.inner.execute(&sub, ctx);
            for (pos, &b) in plain.iter().enumerate() {
                for h in 0..heads {
                    out.slice_mut(b * heads + h)
                        .copy_from_slice(o.view(pos * heads + h).data);
                }
            }
        }

        // session sequences: cache transaction + span solve or
        // full-recompute fallback, per sequence
        for b in 0..bsz {
            let Some(sref) = sessions[b] else { continue };
            // linear-family causal sessions ride the recurrent path:
            // O(m·D²) per step, independent of history length
            if matches!(self.plan, FamilyPlan::Recurrent) && batch.causal {
                outcomes[b] = self.recurrent_seq(batch, b, sref, &mut out,
                                                 ctx);
                continue;
            }
            let valid = batch.valid_len(b);
            let span = sref.span_start;
            let seed2 = session_seed(batch.seed, sref.cache.session);
            let rows_of = |t: &BatchMatrix, r0: usize, r1: usize| {
                (0..heads)
                    .map(|h| seq_rows(t, b * heads + h, r0, r1))
                    .collect::<Vec<Matrix>>()
            };
            let hit = self.cache.step(sref.cache, heads, dk, dv, span,
                                      &rows_of(q, span, valid),
                                      &rows_of(k, span, valid),
                                      &rows_of(v, span, valid));
            match hit {
                Some(data) => {
                    let mut reclustered = false;
                    let mut computed = valid - span;
                    // a frozen model is only ever consulted when
                    // growth > 1; capturing one below that threshold
                    // would be stored and never read
                    let want_model = self.cache.opts.growth > 1.0;
                    let mut models = Vec::new();
                    for h in 0..heads {
                        let mut rng = slice_stream(seed2, h as u64);
                        // the store lock is long gone — assemble the
                        // contiguous panels from the Arc snapshots here
                        let (qf, kf, vf) = (data.q[h].to_matrix(),
                                            data.k[h].to_matrix(),
                                            data.v[h].to_matrix());
                        let span_out = if data.reuse {
                            let model =
                                &data.model.as_ref().unwrap()[h];
                            reuse_head(model, &self.plan,
                                       &qf.row_span(span, valid), &kf,
                                       &vf, ctx)
                        } else {
                            match self.plan {
                                FamilyPlan::Span { full_recompute } => {
                                    if full_recompute {
                                        computed = valid;
                                    }
                                    self.kernel
                                        .solve(&AttnProblem::new(&qf, &kf,
                                                                 &vf)
                                               .with_query_span(span),
                                               &mut rng, ctx)
                                        .row_span(span, valid)
                                }
                                // bidirectional linear sessions: the
                                // kernel's span path is genuinely
                                // incremental over the cached panels
                                FamilyPlan::Recurrent => self
                                    .kernel
                                    .solve(&AttnProblem::new(&qf, &kf,
                                                             &vf)
                                           .with_query_span(span),
                                           &mut rng, ctx)
                                    .row_span(span, valid),
                                FamilyPlan::ClusterModel {
                                    clusters, bits, iters, topk,
                                } => {
                                    reclustered = true;
                                    if topk.is_some() {
                                        // improved re-cluster = full
                                        // solve + span extraction
                                        computed = valid;
                                    }
                                    let (o, m) = recluster_head(
                                        clusters, bits, iters, topk, &qf,
                                        &kf, &vf, span, want_model,
                                        &mut rng, ctx);
                                    if let Some(m) = m {
                                        models.push(m);
                                    }
                                    o
                                }
                            }
                        };
                        let dst = out.slice_mut(b * heads + h);
                        dst[span * dv..valid * dv]
                            .copy_from_slice(&span_out.data);
                    }
                    if reclustered && !models.is_empty() {
                        self.cache.store_model(sref.cache, models, valid);
                    }
                    outcomes[b] = SeqOutcome::Hit {
                        reused_rows: span,
                        computed_rows: computed,
                        reclustered,
                    };
                }
                None => {
                    // full recompute through the wrapped backend with
                    // the session streams, then repopulate
                    let fq = gather(q, &[b]);
                    let fk = gather(k, &[b]);
                    let fv = gather(v, &[b]);
                    let lens = [valid];
                    let sub = AttnBatch::new(&fq, &fk, &fv, seed2)
                        .with_lens(&lens)
                        .with_causal(batch.causal);
                    let o = self.inner.execute(&sub, ctx);
                    for h in 0..heads {
                        out.slice_mut(b * heads + h)
                            .copy_from_slice(o.view(h).data);
                    }
                    self.cache.populate(sref.cache, heads, dk, dv,
                                        rows_of(q, 0, valid),
                                        rows_of(k, 0, valid),
                                        rows_of(v, 0, valid));
                    self.cache
                        .counters
                        .recomputed_rows
                        .fetch_add(valid as u64, Ordering::Relaxed);
                    outcomes[b] = SeqOutcome::Miss {
                        recomputed_rows: valid,
                    };
                }
            }
        }
        (out, outcomes)
    }

    /// One linear-family *causal* session sequence: a recurrent cache
    /// transaction plus an O(m·D²) span walk, or a full causal
    /// recompute + accumulator repopulation on a miss.
    ///
    /// On a hit the per-head state snapshot covers rows `0..span`; the
    /// walk absorbs each new K/V row then emits its output row — the
    /// exact elementary order of
    /// [`causal_linear_attention_span_ctx`], which is what makes the
    /// cached step bit-identical to the full recompute.  No RNG is
    /// consumed (the linear kernel draws nothing).
    ///
    /// [`causal_linear_attention_span_ctx`]:
    /// super::linear::causal_linear_attention_span_ctx
    fn recurrent_seq(&self, batch: &AttnBatch<'_>, b: usize,
                     sref: SessionRef, out: &mut BatchMatrix,
                     ctx: &ExecCtx) -> SeqOutcome {
        let (q, k, v) = (batch.q, batch.k, batch.v);
        let heads = q.heads;
        let (dk, dv) = (q.cols, v.cols);
        let valid = batch.valid_len(b);
        let span = sref.span_start;
        let seed2 = session_seed(batch.seed, sref.cache.session);
        let rows_of = |t: &BatchMatrix, r0: usize, r1: usize| {
            (0..heads)
                .map(|h| seq_rows(t, b * heads + h, r0, r1))
                .collect::<Vec<Matrix>>()
        };
        let new_k = rows_of(k, span, valid);
        let new_v = rows_of(v, span, valid);
        match self.cache.step_recurrent(sref.cache, heads, dk, dv, span,
                                        &new_k, &new_v) {
            Some(states) => {
                for (h, mut state) in states.into_iter().enumerate() {
                    let qd = q.view(b * heads + h).data;
                    let dst = out.slice_mut(b * heads + h);
                    for r in 0..valid - span {
                        state.absorb(new_k[h].row(r), new_v[h].row(r));
                        let i = span + r;
                        state.emit(&qd[i * dk..(i + 1) * dk],
                                   &mut dst[i * dv..(i + 1) * dv]);
                    }
                }
                SeqOutcome::Hit {
                    reused_rows: span,
                    computed_rows: valid - span,
                    reclustered: false,
                }
            }
            None => {
                let fq = gather(q, &[b]);
                let fk = gather(k, &[b]);
                let fv = gather(v, &[b]);
                let lens = [valid];
                let sub = AttnBatch::new(&fq, &fk, &fv, seed2)
                    .with_lens(&lens)
                    .with_causal(true);
                let o = self.inner.execute(&sub, ctx);
                for h in 0..heads {
                    out.slice_mut(b * heads + h)
                        .copy_from_slice(o.view(h).data);
                }
                self.cache.populate_recurrent(sref.cache, heads, dk, dv,
                                              &rows_of(k, 0, valid),
                                              &rows_of(v, 0, valid));
                self.cache
                    .counters
                    .recomputed_rows
                    .fetch_add(valid as u64, Ordering::Relaxed);
                SeqOutcome::Miss { recomputed_rows: valid }
            }
        }
    }
}

impl AttentionBackend for CachingBackend {
    fn backend_name(&self) -> String {
        format!("cached:{}", self.inner.backend_name())
    }

    fn execute(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx)
               -> BatchMatrix {
        self.execute_with_report(batch, ctx).0
    }
}

/// Exact re-cluster step of one head: fresh clustering over the full
/// query history (the same LSH + Lloyd sequence — and RNG draws — a
/// spanless kernel solve performs), the span attended through its
/// affected clusters, and (when `want_model`, i.e. the growth policy
/// can ever reuse it) the frozen model for later steps.
#[allow(clippy::too_many_arguments)]
fn recluster_head(clusters: usize, bits: usize, iters: usize,
                  topk: Option<usize>, qf: &Matrix, kf: &Matrix,
                  vf: &Matrix, span: usize, want_model: bool,
                  rng: &mut crate::prng::Xoshiro256, ctx: &ExecCtx)
                  -> (Matrix, Option<HeadModel>) {
    let lsh = Lsh::new(qf.cols, bits, rng);
    let codes = lsh.hash_ctx(qf, ctx);
    let (cl, cent_codes) =
        hamming_kmeans_model_ctx(&codes, clusters, iters, None, ctx);
    let (span_out, cent) = match topk {
        None => {
            let cent = centroids(qf, &cl);
            let o = clustered_span_attention_ctx(&cl.groups[span..],
                                                 &cent, kf, vf, ctx);
            (o, Some(cent))
        }
        Some(t) => {
            let o = improved_clustered_attention_ctx(qf, kf, vf, &cl, t,
                                                     ctx)
                .row_span(span, qf.rows);
            // the improved path computes its centroids internally —
            // only build the frozen copy when it will ever be read
            (o, want_model.then(|| centroids(qf, &cl)))
        }
    };
    let model = match (want_model, cent) {
        (true, Some(cent_real)) => Some(HeadModel {
            bits,
            proj: lsh.proj,
            cent_codes,
            cent_real,
        }),
        _ => None,
    };
    (span_out, model)
}

/// Frozen-model step of one head: hash the new queries with the stored
/// projections, assign them to the stored Hamming centroids, attend
/// through the affected clusters' frozen real centroids over the full
/// cached keys.  Deterministic (no RNG, row-partitioned ops only), but
/// approximate relative to a fresh clustering — see the module docs.
fn reuse_head(model: &HeadModel, plan: &FamilyPlan, q_new: &Matrix,
              kf: &Matrix, vf: &Matrix, ctx: &ExecCtx) -> Matrix {
    let n_clusters = model.cent_real.rows;
    let lsh = Lsh { bits: model.bits, proj: model.proj.clone() };
    let codes = lsh.hash_ctx(q_new, ctx);
    let mut groups = vec![0u32; q_new.rows];
    assign_nearest(&codes, &model.cent_codes, n_clusters, &mut groups,
                   ctx);
    match plan {
        FamilyPlan::ClusterModel { topk: Some(t), .. } => {
            improved_reuse(&model.cent_real, *t, &groups, q_new, kf, vf)
        }
        _ => clustered_span_attention_ctx(&groups, &model.cent_real, kf,
                                          vf, ctx),
    }
}

/// Improved-clustered refinement against a frozen clustering: per
/// affected cluster, the centroid's attention row over all keys, its
/// top-k mass and complement basis (eqs. 9–17 with the frozen
/// centroid), then the per-new-query top-k softmax.
fn improved_reuse(cent: &Matrix, topk: usize, groups: &[u32],
                  q_new: &Matrix, kf: &Matrix, vf: &Matrix) -> Matrix {
    let (n, dv) = (kf.rows, vf.cols);
    let scale = 1.0 / (kf.cols as f32).sqrt();
    let mut affected: Vec<usize> =
        groups.iter().map(|&g| g as usize).collect();
    affected.sort_unstable();
    affected.dedup();
    // per affected cluster: top-k keys, captured mass, complement basis
    let mut per_cluster: BTreeMap<usize, (Vec<usize>, f32, Vec<f32>)> =
        BTreeMap::new();
    let mut arow = vec![0f32; n];
    for &j in &affected {
        for (l, a) in arow.iter_mut().enumerate() {
            *a = dot(cent.row(j), kf.row(l)) * scale;
        }
        softmax_inplace(&mut arow);
        let idx = topk_indices(&arow, topk);
        // ct-lint: allow(det-float-reduce, reason = "ordered sum over the top-k index list; iteration order is fixed by topk_indices, so the reduction order is deterministic")
        let mhat: f32 = idx.iter().map(|&l| arow[l]).sum();
        let mut vb = vec![0f32; dv];
        for (l, &a) in arow.iter().enumerate() {
            axpy(&mut vb, a, vf.row(l));
        }
        for &l in &idx {
            axpy(&mut vb, -arow[l], vf.row(l));
        }
        per_cluster.insert(j, (idx, mhat, vb));
    }
    let mut out = Matrix::zeros(q_new.rows, dv);
    let mut dots = vec![0f32; topk];
    for i in 0..q_new.rows {
        let (idx, mhat, vb) = &per_cluster[&(groups[i] as usize)];
        let t = idx.len();
        for (slot, &l) in idx.iter().enumerate() {
            dots[slot] = dot(q_new.row(i), kf.row(l)) * scale;
        }
        softmax_inplace(&mut dots[..t]);
        let orow = out.row_mut(i);
        orow.copy_from_slice(vb);
        for (slot, &l) in idx.iter().enumerate() {
            axpy(orow, dots[slot] * *mhat, vf.row(l));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::problem::SessionRef;
    use crate::exec::WorkerPool;
    use crate::prng::Xoshiro256;

    const H: usize = 2;
    const D: usize = 8;

    fn history(n: usize, seed: u64)
               -> (BatchMatrix, BatchMatrix, BatchMatrix) {
        let mut rng = Xoshiro256::new(seed);
        (BatchMatrix::randn(1, H, n, D, &mut rng),
         BatchMatrix::randn(1, H, n, D, &mut rng),
         BatchMatrix::randn(1, H, n, D, &mut rng))
    }

    /// Prefix of a (1, H, N, D) history as an equally tall batch whose
    /// rows `len..` are garbage the contract must ignore.
    fn prefix(t: &BatchMatrix, len: usize) -> BatchMatrix {
        let mut rng = Xoshiro256::new(0xBAD);
        let mut out =
            BatchMatrix::randn(1, H, t.rows, t.cols, &mut rng);
        for s in 0..t.slices() {
            let cols = t.cols;
            out.slice_mut(s)[..len * cols]
                .copy_from_slice(&t.view(s).data[..len * cols]);
        }
        out
    }

    /// The oracle: full unpadded recompute of the history with the
    /// session streams, per head, sliced to the span.
    fn oracle_span(kernel: &str, q: &BatchMatrix, k: &BatchMatrix,
                   v: &BatchMatrix, len: usize, span: usize, seed: u64,
                   sid: u64) -> Vec<Matrix> {
        let kern = crate::attention::kernel_by_name(kernel).unwrap();
        let seed2 = session_seed(seed, sid);
        (0..H)
            .map(|h| {
                let (qh, kh, vh) = (q.slice_valid(h, len),
                                    k.slice_valid(h, len),
                                    v.slice_valid(h, len));
                let mut rng = slice_stream(seed2, h as u64);
                kern.solve(&AttnProblem::new(&qh, &kh, &vh), &mut rng,
                           &ExecCtx::sequential())
                    .row_span(span, len)
            })
            .collect()
    }

    /// The causal oracle: full *causal* recompute of the history with
    /// the session streams, per head, sliced to the span (linear
    /// family — the only causal-capable one).
    fn causal_oracle_span(q: &BatchMatrix, k: &BatchMatrix,
                          v: &BatchMatrix, len: usize, span: usize,
                          seed: u64, sid: u64) -> Vec<Matrix> {
        let kern = crate::attention::kernel_by_name("linear").unwrap();
        let seed2 = session_seed(seed, sid);
        (0..H)
            .map(|h| {
                let (qh, kh, vh) = (q.slice_valid(h, len),
                                    k.slice_valid(h, len),
                                    v.slice_valid(h, len));
                let mut rng = slice_stream(seed2, h as u64);
                kern.solve(&AttnProblem::new(&qh, &kh, &vh)
                               .with_causal(true),
                           &mut rng, &ExecCtx::sequential())
                    .row_span(span, len)
            })
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn run_step_with(backend: &CachingBackend, q: &BatchMatrix,
                     k: &BatchMatrix, v: &BatchMatrix, len: usize,
                     span: usize, seed: u64, sid: u64, gen: u64,
                     workers: usize, causal: bool)
                     -> (BatchMatrix, SeqOutcome) {
        let (qp, kp, vp) = (prefix(q, len), prefix(k, len), prefix(v, len));
        let lens = [len];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: sid, generation: gen },
            span_start: span,
        })];
        let batch = AttnBatch::new(&qp, &kp, &vp, seed)
            .with_lens(&lens)
            .with_sessions(&sessions)
            .with_causal(causal);
        let ctx = if workers <= 1 {
            ExecCtx::sequential()
        } else {
            ExecCtx::with_par_rows(WorkerPool::new(workers), 1)
        };
        let (out, rep) = backend.execute_with_report(&batch, &ctx);
        (out, rep[0])
    }

    fn run_step(backend: &CachingBackend, q: &BatchMatrix,
                k: &BatchMatrix, v: &BatchMatrix, len: usize,
                span: usize, seed: u64, sid: u64, gen: u64, workers: usize)
                -> (BatchMatrix, SeqOutcome) {
        run_step_with(backend, q, k, v, len, span, seed, sid, gen,
                      workers, false)
    }

    fn run_step_causal(backend: &CachingBackend, q: &BatchMatrix,
                       k: &BatchMatrix, v: &BatchMatrix, len: usize,
                       span: usize, seed: u64, sid: u64, gen: u64,
                       workers: usize) -> (BatchMatrix, SeqOutcome) {
        run_step_with(backend, q, k, v, len, span, seed, sid, gen,
                      workers, true)
    }

    fn assert_span_matches(out: &BatchMatrix, want: &[Matrix],
                           span: usize, len: usize, tag: &str) {
        for (h, w) in want.iter().enumerate() {
            let got = seq_rows(out, h, span, len);
            assert!(got.bit_identical(w),
                    "{tag}: head {h} span {span}..{len} diverged");
        }
    }

    #[test]
    fn incremental_steps_match_full_recompute_per_family() {
        let n = 24;
        let (q, k, v) = history(n, 1);
        for kernel in ["full", "shared-full", "oracle-top-4",
                       "clustered-3", "i-clustered-3", "lsh-1",
                       "linear"] {
            let cache = Arc::new(KvCache::unbounded());
            let backend =
                CachingBackend::native(kernel, cache.clone()).unwrap();
            // prefill 10, then steps to 17 and 24, varied worker counts
            let plan = [(10usize, 0usize, 1usize), (17, 10, 3), (24, 17, 2)];
            for (i, &(len, span, workers)) in plan.iter().enumerate() {
                let (out, outcome) = run_step(&backend, &q, &k, &v, len,
                                              span, 7, 42, 0, workers);
                let want = oracle_span(kernel, &q, &k, &v, len, span, 7,
                                       42);
                assert_span_matches(&out, &want, span, len, kernel);
                if i == 0 {
                    assert!(matches!(outcome,
                                     SeqOutcome::Miss { recomputed_rows }
                                     if recomputed_rows == len),
                            "{kernel}: prefill should miss");
                } else {
                    // honest executed-rows accounting: lsh and
                    // improved (which re-clusters every step at the
                    // default growth) recompute the full history;
                    // everything else materializes only the span
                    let want_computed =
                        if kernel == "lsh-1" || kernel == "i-clustered-3"
                        { len } else { len - span };
                    assert!(matches!(outcome,
                                     SeqOutcome::Hit { reused_rows,
                                                       computed_rows,
                                                       .. }
                                     if reused_rows == span
                                        && computed_rows == want_computed),
                            "{kernel}: step should hit with \
                             computed_rows {want_computed}, got \
                             {outcome:?}");
                    // a hit computes only the span: the skipped prefix
                    // rows of the output slices stay zero
                    for h in 0..H {
                        let pre = seq_rows(&out, h, 0, span);
                        assert!(pre.data.iter().all(|&x| x == 0.0),
                                "{kernel}: head {h} pre-span not zero");
                    }
                }
            }
            assert_eq!(cache.session_len(
                CacheRef { session: 42, generation: 0 }), Some(n));
            assert!(cache.counters().hit_rate() > 0.5);
        }
    }

    #[test]
    fn zero_capacity_store_always_misses_but_stays_exact() {
        let (q, k, v) = history(16, 2);
        let cache = Arc::new(KvCache::with_capacity(0));
        let backend = CachingBackend::native("full", cache.clone())
            .unwrap();
        for &(len, span) in &[(8usize, 0usize), (12, 8), (16, 12)] {
            let (out, outcome) =
                run_step(&backend, &q, &k, &v, len, span, 3, 5, 0, 1);
            let want = oracle_span("full", &q, &k, &v, len, span, 3, 5);
            assert_span_matches(&out, &want, span, len, "cap0");
            assert!(matches!(outcome, SeqOutcome::Miss { .. }));
        }
        assert_eq!(cache.used_rows(), 0);
        assert_eq!(cache.counters().hits.load(Ordering::Relaxed), 0);
        assert_eq!(cache.counters().misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn stale_generation_misses_and_never_aliases() {
        let (q, k, v) = history(16, 3);
        let cache = Arc::new(KvCache::unbounded());
        let backend = CachingBackend::native("full", cache.clone())
            .unwrap();
        // generation 0 populates
        let _ = run_step(&backend, &q, &k, &v, 8, 0, 9, 1, 0, 1);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), Some(8));
        // a *different history* under generation 1 must not see gen 0
        let (q2, k2, v2) = history(16, 4);
        let (out, outcome) =
            run_step(&backend, &q2, &k2, &v2, 12, 8, 9, 1, 1, 1);
        assert!(matches!(outcome, SeqOutcome::Miss { .. }),
                "stale generation must miss");
        let want = oracle_span("full", &q2, &k2, &v2, 12, 8, 9, 1);
        assert_span_matches(&out, &want, 8, 12, "gen-bump");
        // the stale entry is gone; the new generation owns the id
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), None);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 1 }), Some(12));
    }

    #[test]
    fn eviction_mid_session_falls_back_to_recompute_bit_identically() {
        let (q, k, v) = history(20, 5);
        // capacity of exactly the prefill: the first decode step's
        // append overflows and evicts the session itself
        let cache = Arc::new(KvCache::with_capacity(10));
        let backend = CachingBackend::native("full", cache.clone())
            .unwrap();
        let (_, o0) = run_step(&backend, &q, &k, &v, 10, 0, 11, 7, 0, 1);
        assert!(matches!(o0, SeqOutcome::Miss { .. }));
        assert_eq!(cache.used_rows(), 10);
        // step appends to 14 > 10: the hit still computes (clones are
        // taken first), then the entry is evicted
        let (out1, o1) =
            run_step(&backend, &q, &k, &v, 14, 10, 11, 7, 0, 2);
        assert!(matches!(o1, SeqOutcome::Hit { reused_rows: 10, .. }));
        assert_span_matches(&out1,
                            &oracle_span("full", &q, &k, &v, 14, 10, 11,
                                         7),
                            10, 14, "pre-evict step");
        assert_eq!(cache.used_rows(), 0, "over-capacity entry evicted");
        assert!(cache.counters().evictions.load(Ordering::Relaxed) >= 1);
        // next step finds nothing: full recompute, bit-identical
        let (out2, o2) =
            run_step(&backend, &q, &k, &v, 18, 14, 11, 7, 0, 1);
        assert!(matches!(o2, SeqOutcome::Miss { recomputed_rows: 18 }));
        assert_span_matches(&out2,
                            &oracle_span("full", &q, &k, &v, 18, 14, 11,
                                         7),
                            14, 18, "post-evict step");
    }

    #[test]
    fn lru_eviction_prefers_the_coldest_session() {
        let cache = KvCache::with_capacity(20);
        let panels = |n: usize, seed: u64| -> Vec<Matrix> {
            let mut rng = Xoshiro256::new(seed);
            (0..H).map(|_| Matrix::randn(n, D, &mut rng)).collect()
        };
        let r = |sid: u64| CacheRef { session: sid, generation: 0 };
        cache.populate(r(1), H, D, D, panels(8, 1), panels(8, 2),
                       panels(8, 3));
        cache.populate(r(2), H, D, D, panels(8, 4), panels(8, 5),
                       panels(8, 6));
        assert_eq!(cache.used_rows(), 16);
        // touching session 1 makes session 2 the LRU victim
        assert_eq!(cache.session_len(r(1)), Some(8));
        let _ = cache.step(r(1), H, D, D, 8, &panels(2, 7),
                           &panels(2, 8), &panels(2, 9));
        cache.populate(r(3), H, D, D, panels(8, 10), panels(8, 11),
                       panels(8, 12));
        assert_eq!(cache.session_len(r(2)), None, "LRU evicted");
        assert_eq!(cache.session_len(r(1)), Some(10));
        assert_eq!(cache.session_len(r(3)), Some(8));
        assert_eq!(cache.used_rows(), 18);
    }

    #[test]
    fn plain_sequences_bypass_and_match_the_wrapped_backend() {
        // a sessions array of all-None entries must ride the inner
        // backend with the ordinary slot streams
        let mut rng = Xoshiro256::new(6);
        let q = BatchMatrix::randn(2, H, 12, D, &mut rng);
        let k = BatchMatrix::randn(2, H, 12, D, &mut rng);
        let v = BatchMatrix::randn(2, H, 12, D, &mut rng);
        let lens = [9usize, 12];
        let sessions: [Option<SessionRef>; 2] = [None, None];
        let cache = Arc::new(KvCache::unbounded());
        let backend =
            CachingBackend::native("clustered-3", cache.clone()).unwrap();
        let batch = AttnBatch::new(&q, &k, &v, 13)
            .with_lens(&lens)
            .with_sessions(&sessions);
        let ctx = ExecCtx::sequential();
        let (out, rep) = backend.execute_with_report(&batch, &ctx);
        assert_eq!(rep, vec![SeqOutcome::Bypass; 2]);
        let inner = NativeBackend::by_name("clustered-3").unwrap();
        let plain = AttnBatch::new(&q, &k, &v, 13).with_lens(&lens);
        assert!(out.bit_identical(&inner.execute(&plain, &ctx)));
        assert_eq!(cache.used_rows(), 0);
    }

    #[test]
    fn frozen_model_reuse_kicks_in_above_growth_one() {
        let n = 32;
        let (q, k, v) = history(n, 8);
        for kernel in ["clustered-3", "i-clustered-3"] {
            let cache = Arc::new(KvCache::new(KvCacheOptions {
                growth: 1.5,
                ..KvCacheOptions::default()
            }));
            let backend =
                CachingBackend::native(kernel, cache.clone()).unwrap();
            // prefill 16 (miss), step to 20 (hit, re-cluster: no model
            // yet), step to 24 (reuse: 24 <= 1.5·20), step to 32
            // (re-cluster: 32 > 1.5·20)
            let (_, o0) =
                run_step(&backend, &q, &k, &v, 16, 0, 21, 9, 0, 1);
            assert!(matches!(o0, SeqOutcome::Miss { .. }), "{kernel}");
            let (out1, o1) =
                run_step(&backend, &q, &k, &v, 20, 16, 21, 9, 0, 1);
            assert!(matches!(o1, SeqOutcome::Hit { reused_rows: 16,
                                                   reclustered: true,
                                                   .. }),
                    "{kernel}: first hit must re-cluster, got {o1:?}");
            // the re-cluster step is exact
            assert_span_matches(&out1,
                                &oracle_span(kernel, &q, &k, &v, 20, 16,
                                             21, 9),
                                16, 20, kernel);
            let (out2, o2) =
                run_step(&backend, &q, &k, &v, 24, 20, 21, 9, 0, 1);
            assert!(matches!(o2, SeqOutcome::Hit { reused_rows: 20,
                                                   computed_rows: 4,
                                                   reclustered: false }),
                    "{kernel}: inside the threshold must reuse, got \
                     {o2:?}");
            // reused steps are deterministic across worker counts...
            for workers in [2, 4] {
                let cache_b = Arc::new(KvCache::new(KvCacheOptions {
                    growth: 1.5,
                    ..KvCacheOptions::default()
                }));
                let backend_b =
                    CachingBackend::native(kernel, cache_b).unwrap();
                let _ = run_step(&backend_b, &q, &k, &v, 16, 0, 21, 9, 0,
                                 workers);
                let _ = run_step(&backend_b, &q, &k, &v, 20, 16, 21, 9,
                                 0, workers);
                let (out2b, _) = run_step(&backend_b, &q, &k, &v, 24, 20,
                                          21, 9, 0, workers);
                assert!(out2b.bit_identical(&out2),
                        "{kernel}: reuse diverged at {workers} workers");
            }
            // ...and finite with the right shape
            let got = seq_rows(&out2, 0, 20, 24);
            assert!(got.data.iter().all(|x| x.is_finite()), "{kernel}");
            // crossing the threshold re-clusters and is exact again
            let (out3, o3) =
                run_step(&backend, &q, &k, &v, 32, 24, 21, 9, 0, 2);
            assert!(matches!(o3, SeqOutcome::Hit { reused_rows: 24,
                                                   reclustered: true,
                                                   .. }),
                    "{kernel}: crossing the threshold re-clusters, got \
                     {o3:?}");
            assert_span_matches(&out3,
                                &oracle_span(kernel, &q, &k, &v, 32, 24,
                                             21, 9),
                                24, 32, kernel);
        }
    }

    #[test]
    fn recurrent_steps_match_the_full_causal_recompute() {
        let n = 24;
        let (q, k, v) = history(n, 12);
        let cache = Arc::new(KvCache::unbounded());
        let backend =
            CachingBackend::native("linear", cache.clone()).unwrap();
        let plan = [(10usize, 0usize, 1usize), (17, 10, 3), (24, 17, 2)];
        for (i, &(len, span, workers)) in plan.iter().enumerate() {
            let (out, outcome) = run_step_causal(&backend, &q, &k, &v,
                                                 len, span, 7, 42, 0,
                                                 workers);
            let want = causal_oracle_span(&q, &k, &v, len, span, 7, 42);
            assert_span_matches(&out, &want, span, len,
                                "linear-recurrent");
            if i == 0 {
                assert!(matches!(outcome,
                                 SeqOutcome::Miss { recomputed_rows }
                                 if recomputed_rows == len),
                        "prefill should miss");
            } else {
                assert!(matches!(outcome,
                                 SeqOutcome::Hit { reused_rows,
                                                   computed_rows,
                                                   reclustered: false }
                                 if reused_rows == span
                                    && computed_rows == len - span),
                        "recurrent step should hit with computed_rows \
                         {}, got {outcome:?}", len - span);
                // only the span is computed: pre-span rows stay zero
                for h in 0..H {
                    let pre = seq_rows(&out, h, 0, span);
                    assert!(pre.data.iter().all(|&x| x == 0.0),
                            "head {h} pre-span not zero");
                }
            }
        }
        assert_eq!(cache.session_len(
            CacheRef { session: 42, generation: 0 }), Some(n));
        // the accumulator charges its constant row-equivalent, not len
        assert_eq!(cache.used_rows(), recurrent_rows_equiv(D, D));
        assert!(cache.counters().hit_rate() > 0.5);
    }

    #[test]
    fn recurrent_zero_capacity_always_misses_but_stays_exact() {
        let (q, k, v) = history(16, 13);
        let cache = Arc::new(KvCache::with_capacity(0));
        let backend =
            CachingBackend::native("linear", cache.clone()).unwrap();
        for &(len, span) in &[(8usize, 0usize), (12, 8), (16, 12)] {
            let (out, outcome) = run_step_causal(&backend, &q, &k, &v,
                                                 len, span, 3, 5, 0, 1);
            let want = causal_oracle_span(&q, &k, &v, len, span, 3, 5);
            assert_span_matches(&out, &want, span, len,
                                "recurrent-cap0");
            assert!(matches!(outcome, SeqOutcome::Miss { .. }));
        }
        assert_eq!(cache.used_rows(), 0);
        assert_eq!(cache.counters().hits.load(Ordering::Relaxed), 0);
        assert_eq!(cache.counters().misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn recurrent_stale_generation_misses_and_never_aliases() {
        let (q, k, v) = history(16, 14);
        let cache = Arc::new(KvCache::unbounded());
        let backend =
            CachingBackend::native("linear", cache.clone()).unwrap();
        // generation 0 populates an accumulator
        let _ = run_step_causal(&backend, &q, &k, &v, 8, 0, 9, 1, 0, 1);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), Some(8));
        // a *different history* under generation 1 must not see gen 0's
        // accumulator (an aliased S/z would corrupt every later step)
        let (q2, k2, v2) = history(16, 15);
        let (out, outcome) =
            run_step_causal(&backend, &q2, &k2, &v2, 12, 8, 9, 1, 1, 1);
        assert!(matches!(outcome, SeqOutcome::Miss { .. }),
                "stale generation must miss");
        let want = causal_oracle_span(&q2, &k2, &v2, 12, 8, 9, 1);
        assert_span_matches(&out, &want, 8, 12, "recurrent-gen-bump");
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), None);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 1 }), Some(12));
    }

    #[test]
    fn recurrent_eviction_falls_back_to_recompute_bit_identically() {
        // an accumulator's charge never grows, so it cannot evict
        // itself by stepping — eviction pressure comes from a
        // *competing* session in a store that fits exactly one
        let (q, k, v) = history(20, 16);
        let (q2, k2, v2) = history(20, 17);
        let cache = Arc::new(KvCache::with_capacity(
            recurrent_rows_equiv(D, D)));
        let backend =
            CachingBackend::native("linear", cache.clone()).unwrap();
        // session 7 prefills and owns the store
        let (_, o0) =
            run_step_causal(&backend, &q, &k, &v, 10, 0, 11, 7, 0, 1);
        assert!(matches!(o0, SeqOutcome::Miss { .. }));
        assert_eq!(cache.used_rows(), recurrent_rows_equiv(D, D));
        // session 8's prefill evicts session 7 (LRU)
        let (_, o1) =
            run_step_causal(&backend, &q2, &k2, &v2, 10, 0, 11, 8, 0, 1);
        assert!(matches!(o1, SeqOutcome::Miss { .. }));
        assert!(cache.counters().evictions.load(Ordering::Relaxed) >= 1);
        assert_eq!(cache.session_len(
            CacheRef { session: 7, generation: 0 }), None, "LRU evicted");
        // session 7's next step misses, recomputes bit-identically...
        let (out2, o2) =
            run_step_causal(&backend, &q, &k, &v, 14, 10, 11, 7, 0, 2);
        assert!(matches!(o2, SeqOutcome::Miss { recomputed_rows: 14 }));
        assert_span_matches(&out2,
                            &causal_oracle_span(&q, &k, &v, 14, 10, 11,
                                                7),
                            10, 14, "post-evict recurrent step");
        // ...re-owns the store, and the step after hits again
        let (out3, o3) =
            run_step_causal(&backend, &q, &k, &v, 18, 14, 11, 7, 0, 1);
        assert!(matches!(o3, SeqOutcome::Hit { reused_rows: 14,
                                               computed_rows: 4, .. }),
                "got {o3:?}");
        assert_span_matches(&out3,
                            &causal_oracle_span(&q, &k, &v, 18, 14, 11,
                                                7),
                            14, 18, "re-owned recurrent step");
    }

    #[test]
    fn recurrent_and_panel_entries_share_capacity_and_lru() {
        // capacity fits one 8-row panel session plus one accumulator —
        // both kinds compete in the same LRU order and row budget
        let charge = recurrent_rows_equiv(D, D);
        let cache = KvCache::with_capacity(8 + charge);
        let panels = |n: usize, seed: u64| -> Vec<Matrix> {
            let mut rng = Xoshiro256::new(seed);
            (0..H).map(|_| Matrix::randn(n, D, &mut rng)).collect()
        };
        let r = |sid: u64| CacheRef { session: sid, generation: 0 };
        cache.populate(r(1), H, D, D, panels(8, 1), panels(8, 2),
                       panels(8, 3));
        cache.populate_recurrent(r(2), H, D, D, &panels(8, 4),
                                 &panels(8, 5));
        assert_eq!(cache.used_rows(), 8 + charge);
        // touching the recurrent session makes the panel one the LRU
        // victim of the next populate
        assert!(cache.step_recurrent(r(2), H, D, D, 8, &panels(2, 6),
                                     &panels(2, 7)).is_some());
        cache.populate(r(3), H, D, D, panels(8, 8), panels(8, 9),
                       panels(8, 10));
        assert_eq!(cache.session_len(r(1)), None,
                   "panel entry was the LRU victim");
        assert_eq!(cache.session_len(r(2)), Some(10));
        assert_eq!(cache.session_len(r(3)), Some(8));
        assert_eq!(cache.used_rows(), 8 + charge);
    }

    #[test]
    fn panel_and_recurrent_kinds_never_serve_each_other() {
        // the same session id flipping between causal (recurrent entry)
        // and bidirectional (panel entry) use must miss on every flip,
        // drop the other kind, and stay exact against its own oracle
        let (q, k, v) = history(16, 18);
        let cache = Arc::new(KvCache::unbounded());
        let backend =
            CachingBackend::native("linear", cache.clone()).unwrap();
        // causal prefill → recurrent entry
        let (_, o0) =
            run_step_causal(&backend, &q, &k, &v, 8, 0, 19, 4, 0, 1);
        assert!(matches!(o0, SeqOutcome::Miss { .. }));
        assert_eq!(cache.used_rows(), recurrent_rows_equiv(D, D));
        // a bidirectional step must not read the accumulator
        let (out1, o1) = run_step(&backend, &q, &k, &v, 12, 8, 19, 4, 0,
                                  1);
        assert!(matches!(o1, SeqOutcome::Miss { .. }),
                "kind mismatch must miss, got {o1:?}");
        assert_span_matches(&out1,
                            &oracle_span("linear", &q, &k, &v, 12, 8, 19,
                                         4),
                            8, 12, "recurrent-to-panel flip");
        // the flip repopulated panels, charged by length again
        assert_eq!(cache.used_rows(), 12);
        // ...and back: the panel entry must not serve the causal step
        let (out2, o2) =
            run_step_causal(&backend, &q, &k, &v, 16, 12, 19, 4, 0, 1);
        assert!(matches!(o2, SeqOutcome::Miss { .. }),
                "kind mismatch must miss, got {o2:?}");
        assert_span_matches(&out2,
                            &causal_oracle_span(&q, &k, &v, 16, 12, 19,
                                                4),
                            12, 16, "panel-to-recurrent flip");
        assert_eq!(cache.used_rows(), recurrent_rows_equiv(D, D));
    }

    // ---- quantized-panel edge cases (tolerance-gated mode) ----

    fn quant_cache(capacity_rows: usize, quant: CacheQuant)
                   -> Arc<KvCache> {
        Arc::new(KvCache::new(KvCacheOptions {
            capacity_rows,
            quant,
            ..KvCacheOptions::default()
        }))
    }

    /// Max-abs error of the span rows against the exact f32 oracle.
    fn span_error(out: &BatchMatrix, want: &[Matrix], span: usize,
                  len: usize) -> f32 {
        want.iter()
            .enumerate()
            .map(|(h, w)| seq_rows(out, h, span, len).max_abs_diff(w))
            .fold(0.0, f32::max)
    }

    /// The natural error scale of an attention output: outputs are
    /// convex combinations of V rows, so max|v| bounds their range.
    fn vmax(v: &BatchMatrix) -> f32 {
        v.data.iter().fold(0.0f32, |a, &x| f32::max(a, x.abs()))
    }

    #[test]
    fn quantized_steps_stay_within_tolerance_and_charge_quarter_rows() {
        let n = 24;
        let (q, k, v) = history(n, 31);
        let tol = 0.1 + 0.1 * vmax(&v);
        for quant in [CacheQuant::I8PerHead, CacheQuant::I8PerPanel] {
            let cache = quant_cache(usize::MAX, quant);
            let backend =
                CachingBackend::native("full", cache.clone()).unwrap();
            let plan = [(10usize, 0usize), (17, 10), (24, 17)];
            let mut last = None;
            for (i, &(len, span)) in plan.iter().enumerate() {
                let (out, outcome) = run_step(&backend, &q, &k, &v, len,
                                              span, 7, 42, 0, 1);
                let want = oracle_span("full", &q, &k, &v, len, span, 7,
                                       42);
                if i == 0 {
                    // the miss/prefill path computes from the caller's
                    // raw f32 inputs: bit-exact even with quant on
                    assert!(matches!(outcome, SeqOutcome::Miss { .. }));
                    assert_span_matches(&out, &want, span, len,
                                        "quant prefill");
                } else {
                    assert!(matches!(outcome,
                                     SeqOutcome::Hit { reused_rows, .. }
                                     if reused_rows == span),
                            "{quant:?}: step should hit, got {outcome:?}");
                    let err = span_error(&out, &want, span, len);
                    assert!(err <= tol,
                            "{quant:?}: err {err} beyond tolerance {tol}");
                    assert!(seq_rows(&out, 0, span, len)
                                .data.iter().all(|x| x.is_finite()));
                }
                last = Some(out);
            }
            // the lossy hit path is still deterministic: replaying the
            // same plan at another worker count is bit-identical
            let cache_b = quant_cache(usize::MAX, quant);
            let backend_b =
                CachingBackend::native("full", cache_b).unwrap();
            let mut last_b = None;
            for &(len, span) in &plan {
                let (out, _) = run_step(&backend_b, &q, &k, &v, len,
                                        span, 7, 42, 0, 3);
                last_b = Some(out);
            }
            assert!(last.unwrap().bit_identical(&last_b.unwrap()),
                    "{quant:?}: quantized decode diverged across \
                     worker counts");
            // the 24-row session charges its true byte cost: ⌈24/4⌉
            assert_eq!(cache.used_rows(), quant_rows_equiv(n));
            assert_eq!(cache.used_rows(), 6);
        }
    }

    #[test]
    fn quantized_capacity_zero_store_always_misses_but_stays_exact() {
        let (q, k, v) = history(16, 32);
        let cache = quant_cache(0, CacheQuant::I8PerPanel);
        let backend =
            CachingBackend::native("full", cache.clone()).unwrap();
        for &(len, span) in &[(8usize, 0usize), (12, 8), (16, 12)] {
            let (out, outcome) =
                run_step(&backend, &q, &k, &v, len, span, 3, 5, 0, 1);
            // nothing is ever stored, so nothing is ever dequantized:
            // every step recomputes from raw f32, bit-identically
            let want = oracle_span("full", &q, &k, &v, len, span, 3, 5);
            assert_span_matches(&out, &want, span, len, "quant-cap0");
            assert!(matches!(outcome, SeqOutcome::Miss { .. }));
        }
        assert_eq!(cache.used_rows(), 0);
        assert_eq!(cache.counters().hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn quantized_stale_generation_misses_and_never_aliases() {
        let (q, k, v) = history(16, 33);
        let cache = quant_cache(usize::MAX, CacheQuant::I8PerHead);
        let backend =
            CachingBackend::native("full", cache.clone()).unwrap();
        let _ = run_step(&backend, &q, &k, &v, 8, 0, 9, 1, 0, 1);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), Some(8));
        let (q2, k2, v2) = history(16, 34);
        let (out, outcome) =
            run_step(&backend, &q2, &k2, &v2, 12, 8, 9, 1, 1, 1);
        assert!(matches!(outcome, SeqOutcome::Miss { .. }),
                "stale generation must miss");
        // the miss recomputes from raw f32: bit-exact despite quant
        let want = oracle_span("full", &q2, &k2, &v2, 12, 8, 9, 1);
        assert_span_matches(&out, &want, 8, 12, "quant-gen-bump");
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 0 }), None);
        assert_eq!(cache.session_len(
            CacheRef { session: 1, generation: 1 }), Some(12));
        assert_eq!(cache.used_rows(), quant_rows_equiv(12));
    }

    #[test]
    fn quantized_eviction_mid_session_falls_back_to_exact_recompute() {
        let (q, k, v) = history(20, 35);
        // capacity of exactly the prefill's quantized charge ⌈10/4⌉:
        // the first step's append outgrows it and evicts the session
        let cache = quant_cache(quant_rows_equiv(10),
                                CacheQuant::I8PerPanel);
        let backend =
            CachingBackend::native("full", cache.clone()).unwrap();
        let (_, o0) = run_step(&backend, &q, &k, &v, 10, 0, 11, 7, 0, 1);
        assert!(matches!(o0, SeqOutcome::Miss { .. }));
        assert_eq!(cache.used_rows(), quant_rows_equiv(10));
        let (out1, o1) =
            run_step(&backend, &q, &k, &v, 14, 10, 11, 7, 0, 1);
        assert!(matches!(o1, SeqOutcome::Hit { reused_rows: 10, .. }));
        let tol = 0.1 + 0.1 * vmax(&v);
        let err = span_error(&out1,
                             &oracle_span("full", &q, &k, &v, 14, 10, 11,
                                          7),
                             10, 14);
        assert!(err <= tol, "pre-evict step err {err} beyond {tol}");
        assert_eq!(cache.used_rows(), 0, "over-capacity entry evicted");
        assert!(cache.counters().evictions.load(Ordering::Relaxed) >= 1);
        // the post-eviction step misses and recomputes from raw f32 —
        // the fall-back to the exact path is bit-identical
        let (out2, o2) =
            run_step(&backend, &q, &k, &v, 18, 14, 11, 7, 0, 1);
        assert!(matches!(o2, SeqOutcome::Miss { recomputed_rows: 18 }));
        assert_span_matches(&out2,
                            &oracle_span("full", &q, &k, &v, 18, 14, 11,
                                         7),
                            14, 18, "post-evict quant step");
    }

    #[test]
    fn quantized_and_recurrent_entries_share_one_lru_budget() {
        // the store's quant mode covers panel entries only; recurrent
        // accumulators stay exact f32 — both kinds still compete in
        // the same row budget and LRU order
        let charge_r = recurrent_rows_equiv(D, D);
        let cache = KvCache::new(KvCacheOptions {
            capacity_rows: quant_rows_equiv(8) + charge_r,
            quant: CacheQuant::I8PerPanel,
            ..KvCacheOptions::default()
        });
        let panels = |n: usize, seed: u64| -> Vec<Matrix> {
            let mut rng = Xoshiro256::new(seed);
            (0..H).map(|_| Matrix::randn(n, D, &mut rng)).collect()
        };
        let r = |sid: u64| CacheRef { session: sid, generation: 0 };
        cache.populate(r(1), H, D, D, panels(8, 1), panels(8, 2),
                       panels(8, 3));
        cache.populate_recurrent(r(2), H, D, D, &panels(8, 4),
                                 &panels(8, 5));
        assert_eq!(cache.used_rows(), quant_rows_equiv(8) + charge_r);
        // touching the recurrent session makes the quantized panel
        // entry the LRU victim of the next populate
        assert!(cache.step_recurrent(r(2), H, D, D, 8, &panels(2, 6),
                                     &panels(2, 7)).is_some());
        cache.populate(r(3), H, D, D, panels(8, 8), panels(8, 9),
                       panels(8, 10));
        assert_eq!(cache.session_len(r(1)), None,
                   "quantized panel entry was the LRU victim");
        assert_eq!(cache.session_len(r(2)), Some(10));
        assert_eq!(cache.session_len(r(3)), Some(8));
        assert_eq!(cache.used_rows(), quant_rows_equiv(8) + charge_r);
    }

    #[test]
    fn quantized_all_zero_history_round_trips_bit_exactly() {
        // absmax == 0 pins every scale to 0.0: the dequantized panels
        // are exact zeros, so even the lossy hit path reproduces the
        // exact recompute bit-for-bit
        let zeros = || BatchMatrix::zeros(1, H, 16, D);
        let (q, k, v) = (zeros(), zeros(), zeros());
        for quant in [CacheQuant::I8PerHead, CacheQuant::I8PerPanel] {
            let cache = quant_cache(usize::MAX, quant);
            let backend =
                CachingBackend::native("full", cache.clone()).unwrap();
            let (_, o0) =
                run_step(&backend, &q, &k, &v, 8, 0, 9, 6, 0, 1);
            assert!(matches!(o0, SeqOutcome::Miss { .. }));
            let (out, o1) =
                run_step(&backend, &q, &k, &v, 12, 8, 9, 6, 0, 1);
            assert!(matches!(o1, SeqOutcome::Hit { .. }),
                    "{quant:?}: got {o1:?}");
            let want = oracle_span("full", &q, &k, &v, 12, 8, 9, 6);
            assert_span_matches(&out, &want, 8, 12, "quant-zeros");
        }
    }
}
