//! ct-contract: bit-exact
//!
//! Reformer-style LSH attention baseline: shared-QK, angular LSH
//! bucketing, chunked local attention, rounds combined with logsumexp
//! weights.
//!
//! Positions are processed in `chunk`-sized blocks of the bucket-sorted
//! order; the final block may be **ragged** (`N % chunk != 0` is fine:
//! there are `ceil(N / chunk)` blocks and the last is simply smaller),
//! which is what lets valid-length masking hand this kernel arbitrary
//! unpadded lengths.  For chunk-divisible `N` the blocking — and
//! therefore every output bit — is identical to the historical
//! divisible-only path.
//!
//! ## Sign-bit Hamming fast path (`lsh-ham`)
//!
//! The bucketing pass already computes every rotation dot product, so
//! each position gets a free 8-bit **sign code** (one bit per rotation
//! row).  The [`LshHamAttention`] variant ranks a query's same-bucket
//! candidates by Hamming distance between sign codes — an XNOR/popcount
//! stand-in for the f32 dot products — and keeps only the `topk`
//! closest (plus the position itself) before running the exact softmax
//! over the survivors.  Ranking is deterministic (ties broken by
//! candidate slot, ascending) and the kept logits are computed in f32
//! exactly as the dense path computes them, so the fast path is
//! bit-reproducible; it is *approximate* relative to `lsh-*` only in
//! which candidates survive.  With `topk >= 2·chunk` every same-bucket
//! candidate survives and the output is bit-identical to the exact
//! kernel — the degenerate case the tests pin.

use crate::exec::ExecCtx;
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, Matrix};

use super::{AttentionKernel, AttnProblem, Cost};

/// Shared-QK chunked LSH attention; rounds combined with logsumexp weights.
pub fn reformer_attention(x: &Matrix, v: &Matrix, rounds: usize,
                          chunk: usize, rng: &mut Xoshiro256) -> Matrix {
    reformer_attention_ctx(x, v, rounds, chunk, rng,
                           &ExecCtx::sequential())
}

/// [`reformer_attention`] with the per-position bucketing argmax
/// partitioned over the ctx pool (each position's bucket is a pure
/// function of its row and the round's rotation, so the parallel
/// assignment is bit-identical to the sequential loop).
pub fn reformer_attention_ctx(x: &Matrix, v: &Matrix, rounds: usize,
                              chunk: usize, rng: &mut Xoshiro256,
                              ctx: &ExecCtx) -> Matrix {
    reformer_attention_ham_ctx(x, v, rounds, chunk, None, rng, ctx)
}

/// [`reformer_attention_ctx`] with an optional sign-bit Hamming
/// candidate pre-filter: `ham_topk = Some(t)` keeps, per query, only
/// the `t` same-bucket candidates whose 8-bit sign codes are closest in
/// Hamming distance (plus the query's own position), masking the rest
/// before the f32 softmax.  `None` is the exact dense-candidate path,
/// bit-identical to the historical kernel.
pub fn reformer_attention_ham_ctx(x: &Matrix, v: &Matrix, rounds: usize,
                                  chunk: usize, ham_topk: Option<usize>,
                                  rng: &mut Xoshiro256,
                                  ctx: &ExecCtx) -> Matrix {
    let n = x.rows;
    assert!(chunk >= 1, "chunk must be >= 1");
    if n == 0 {
        return Matrix::zeros(0, v.cols);
    }
    let n_buckets = 16usize;
    let scale = 1.0 / (x.cols as f32).sqrt();

    let mut outs: Vec<Matrix> = Vec::with_capacity(rounds);
    let mut lses: Vec<Vec<f32>> = Vec::with_capacity(rounds);

    for _ in 0..rounds {
        // angular LSH: argmax over [xR; -xR].  The same pass packs the
        // free 8-bit sign code (bit b = sign of rotation row b's dot)
        // above the bucket id — no extra RNG draws or dot products, so
        // the bucket half of the pass is byte-for-byte the historical
        // computation whether or not the Hamming filter is on.
        let rot = Matrix::randn(n_buckets / 2, x.cols, rng);
        let code_of = |i: usize| {
            let (mut best_v, mut best_b) = (f32::NEG_INFINITY, 0usize);
            let mut code = 0usize;
            for b in 0..n_buckets / 2 {
                let h = dot(x.row(i), rot.row(b));
                if h > 0.0 {
                    code |= 1 << b;
                }
                if h > best_v {
                    best_v = h;
                    best_b = b;
                }
                if -h > best_v {
                    best_v = -h;
                    best_b = b + n_buckets / 2;
                }
            }
            (best_b << 8) | code
        };
        let packed: Vec<usize> = ctx.map_indexed(n, code_of);
        let buckets: Vec<usize> =
            packed.iter().map(|&p| p >> 8).collect();
        let codes: Vec<usize> =
            packed.iter().map(|&p| p & 0xFF).collect();
        // stable sort by bucket
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (buckets[i], i));

        let mut out = Matrix::zeros(n, v.cols);
        let mut lse = vec![f32::NEG_INFINITY; n];
        // chunk boundaries: full blocks plus a ragged final block
        let n_chunks = n.div_ceil(chunk);
        let bounds = |c: usize| (c * chunk, ((c + 1) * chunk).min(n));
        for cidx in 0..n_chunks {
            let prev = (cidx + n_chunks - 1) % n_chunks;
            let (p0, p1) = bounds(prev);
            let (c0, c1) = bounds(cidx);
            // candidate keys: previous chunk ++ own chunk
            let cand: Vec<usize> = order[p0..p1]
                .iter()
                .chain(&order[c0..c1])
                .copied()
                .collect();
            for &qi in &order[c0..c1] {
                // Hamming pre-filter: rank same-bucket candidates by
                // sign-code distance, keep the topk closest (ties by
                // candidate slot, ascending — a pinned order) plus the
                // query's own position.  None = keep everything, the
                // exact historical path.
                let keep: Option<Vec<bool>> = ham_topk.map(|t| {
                    let mut ranked: Vec<(u32, usize)> = cand
                        .iter()
                        .enumerate()
                        .filter(|&(_, &kj)| {
                            buckets[kj] == buckets[qi] && kj != qi
                        })
                        .map(|(slot, &kj)| {
                            let d = (codes[kj] ^ codes[qi]) as u32;
                            (d.count_ones(), slot)
                        })
                        .collect();
                    ranked.sort_unstable();
                    let mut keep = vec![false; cand.len()];
                    for &(_, slot) in ranked.iter().take(t) {
                        keep[slot] = true;
                    }
                    for (slot, &kj) in cand.iter().enumerate() {
                        if kj == qi {
                            keep[slot] = true;
                        }
                    }
                    keep
                });
                let mut logits = Vec::with_capacity(cand.len());
                for (slot, &kj) in cand.iter().enumerate() {
                    let pruned = keep
                        .as_ref()
                        .map(|ks| !ks[slot])
                        .unwrap_or(false);
                    let l = if buckets[kj] != buckets[qi] || pruned {
                        f32::NEG_INFINITY
                    } else if kj == qi {
                        -5e8 // self only as a fallback
                    } else {
                        dot(x.row(qi), x.row(kj)) * scale
                    };
                    logits.push(l);
                }
                let m = logits.iter().copied().fold(f32::NEG_INFINITY,
                                                    f32::max);
                let mut sum = 0f32;
                for l in &mut logits {
                    *l = (*l - m).exp();
                    // ct-lint: allow(det-float-accum, reason = "softmax normaliser accumulated over a bucket in ascending key order; the elementary order is the pinned contract")
                    sum += *l;
                }
                lse[qi] = m + sum.max(1e-30).ln();
                let inv = 1.0 / sum.max(1e-30);
                let orow = out.row_mut(qi);
                for (slot, &kj) in cand.iter().enumerate() {
                    if logits[slot] > 0.0 {
                        axpy(orow, logits[slot] * inv, v.row(kj));
                    }
                }
            }
        }
        outs.push(out);
        lses.push(lse);
    }

    // combine rounds: softmax over per-position lse
    let mut combined = Matrix::zeros(n, v.cols);
    for i in 0..n {
        let m = (0..rounds)
            .map(|r| lses[r][i])
            .fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f32> = (0..rounds).map(|r| (lses[r][i] - m).exp())
            .collect();
        // ct-lint: allow(det-float-reduce, reason = "round-weight sum over the fixed rounds vector, ascending; reduction order is pinned")
        let tot: f32 = ws.iter().sum();
        let orow = combined.row_mut(i);
        for r in 0..rounds {
            axpy(orow, ws[r] / tot.max(1e-30), outs[r].row(i));
        }
    }
    combined
}

/// Reformer-style LSH attention kernel (shared QK; `k` input is unused).
#[derive(Debug, Clone, Copy)]
pub struct LshAttention {
    pub rounds: usize,
    pub chunk: usize,
}

impl AttentionKernel for LshAttention {
    fn name(&self) -> String {
        format!("lsh-{}", self.rounds)
    }

    /// Masking = solving the valid-prefix sub-problem: bucketing,
    /// sorting and chunking see only the valid positions (the ragged
    /// final chunk absorbs any length), and the per-round rotation
    /// draws depend only on the head dim — so the masked run is
    /// bit-identical to the unpadded run.
    ///
    /// A `query_span` is honored by computing the full valid solve and
    /// emitting only the span rows (exact by construction): every
    /// position participates in the joint bucket sort and chunk
    /// layout, so there is no cheaper exact span for this family — the
    /// KV cache still removes the per-step history re-upload, but not
    /// the recompute.
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "lsh does not support causal attention");
        let (q, _, v) = p.valid_qkv();
        let out = reformer_attention_ctx(&q, &v, self.rounds, self.chunk,
                                         rng, ctx);
        if p.is_spanned() {
            return p.restore_span(out.row_span(p.span_start(), out.rows));
        }
        p.restore_rows(out)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (r, c) = (self.rounds as u64, self.chunk as u64);
        Cost {
            flops: r * n64 * 2 * c * (dk64 + dv64)
                + r * n64 * dk64 * 8,
            bytes: 4 * r * n64 * 2 * c,
        }
    }
}

/// LSH attention with the sign-bit Hamming candidate pre-filter: per
/// query, only the `topk` same-bucket candidates closest in sign-code
/// Hamming distance get f32 logits (XNOR-style reduced-precision
/// ranking); the rest are masked before the softmax.  Approximate
/// relative to [`LshAttention`] — tolerance-gated at the policy layer —
/// but fully deterministic, and bit-identical to the exact kernel when
/// `topk` covers every candidate (`topk >= 2·chunk`).
#[derive(Debug, Clone, Copy)]
pub struct LshHamAttention {
    pub rounds: usize,
    pub chunk: usize,
    /// Candidates kept per query after Hamming ranking.
    pub topk: usize,
}

impl AttentionKernel for LshHamAttention {
    fn name(&self) -> String {
        format!("lsh-ham-{}", self.rounds)
    }

    /// Masking and span behave exactly as [`LshAttention::solve`]: the
    /// valid-prefix sub-problem is solved jointly (sign codes are
    /// computed only over valid rows), then the span rows are emitted.
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "lsh-ham does not support causal attention");
        let (q, _, v) = p.valid_qkv();
        let out = reformer_attention_ham_ctx(&q, &v, self.rounds,
                                             self.chunk, Some(self.topk),
                                             rng, ctx);
        if p.is_spanned() {
            return p.restore_span(out.row_span(p.span_start(), out.rows));
        }
        p.restore_rows(out)
    }

    /// The candidate window shrinks from `2·chunk` to `topk` f32 dot
    /// products per query; the bucketing pass (and its 8 rotation dots
    /// per position) is unchanged, and the Hamming ranking itself is
    /// XNOR/popcount noise next to the GEMV work.
    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (r, c) = (self.rounds as u64, self.chunk as u64);
        let kept = (self.topk as u64).min(2 * c);
        Cost {
            flops: r * n64 * kept * (dk64 + dv64)
                + r * n64 * dk64 * 8,
            bytes: 4 * r * n64 * 2 * c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ragged_final_chunk_is_well_defined() {
        // N = 2·chunk + tail: there are ceil(N/chunk) blocks, the last
        // one smaller — output stays finite and correctly shaped
        let mut rng = Xoshiro256::new(1);
        let x = Matrix::randn(41, 8, &mut rng);
        let v = Matrix::randn(41, 8, &mut rng);
        let out = reformer_attention(&x, &v, 2, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (41, 8));
        assert!(out.data.iter().all(|f| f.is_finite()));
        // shorter than one chunk: a single ragged block, still defined
        let out = reformer_attention(&x.row_prefix(5), &v.row_prefix(5),
                                     1, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (5, 8));
        assert!(out.data.iter().all(|f| f.is_finite()));
        // empty input short-circuits instead of dividing by zero
        let empty = reformer_attention(&Matrix::zeros(0, 8),
                                       &Matrix::zeros(0, 8), 1, 16,
                                       &mut rng);
        assert_eq!((empty.rows, empty.cols), (0, 8));
    }

    #[test]
    fn identical_inputs_and_rng_streams_are_deterministic() {
        let mut rng = Xoshiro256::new(3);
        let x = Matrix::randn(32, 8, &mut rng);
        let v = Matrix::randn(32, 8, &mut rng);
        let mut r1 = Xoshiro256::new(7);
        let mut r2 = Xoshiro256::new(7);
        let a = reformer_attention(&x, &v, 2, 16, &mut r1);
        let b = reformer_attention(&x, &v, 2, 16, &mut r2);
        assert!(a.bit_identical(&b));
    }

    #[test]
    fn ham_keep_all_is_bit_identical_to_the_exact_kernel() {
        // topk >= 2·chunk keeps every same-bucket candidate, so the
        // Hamming filter is a no-op and the two paths must agree bit
        // for bit — including the shared bucketing RNG draws
        let mut rng = Xoshiro256::new(17);
        let x = Matrix::randn(53, 8, &mut rng);
        let v = Matrix::randn(53, 8, &mut rng);
        let ctx = ExecCtx::sequential();
        let mut r1 = Xoshiro256::new(9);
        let mut r2 = Xoshiro256::new(9);
        let exact = reformer_attention_ctx(&x, &v, 2, 16, &mut r1, &ctx);
        let ham = reformer_attention_ham_ctx(&x, &v, 2, 16, Some(32),
                                             &mut r2, &ctx);
        assert!(ham.bit_identical(&exact));
    }

    #[test]
    fn ham_pruned_output_is_deterministic_and_finite() {
        let mut rng = Xoshiro256::new(19);
        let x = Matrix::randn(64, 8, &mut rng);
        let v = Matrix::randn(64, 8, &mut rng);
        let ctx = ExecCtx::sequential();
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        let a = reformer_attention_ham_ctx(&x, &v, 2, 16, Some(4),
                                           &mut r1, &ctx);
        let b = reformer_attention_ham_ctx(&x, &v, 2, 16, Some(4),
                                           &mut r2, &ctx);
        assert_eq!((a.rows, a.cols), (64, 8));
        assert!(a.data.iter().all(|f| f.is_finite()));
        assert!(a.bit_identical(&b));
        // topk = 0 degenerates to the self-fallback only — still
        // well-defined (each row is some v row, never NaN)
        let mut r3 = Xoshiro256::new(5);
        let z = reformer_attention_ham_ctx(&x, &v, 2, 16, Some(0),
                                           &mut r3, &ctx);
        assert!(z.data.iter().all(|f| f.is_finite()));
    }

    #[test]
    fn ham_kernel_keep_all_matches_the_lsh_kernel() {
        let mut rng = Xoshiro256::new(23);
        let q = Matrix::randn(48, 8, &mut rng);
        let k = Matrix::randn(48, 8, &mut rng);
        let v = Matrix::randn(48, 8, &mut rng);
        let ctx = ExecCtx::sequential();
        let p = AttnProblem::new(&q, &k, &v);
        let mut r1 = Xoshiro256::new(3);
        let mut r2 = Xoshiro256::new(3);
        let exact = LshAttention { rounds: 2, chunk: 16 }
            .solve(&p, &mut r1, &ctx);
        let ham = LshHamAttention { rounds: 2, chunk: 16, topk: 32 }
            .solve(&p, &mut r2, &ctx);
        assert!(ham.bit_identical(&exact));
        // and the pruned cost model is strictly cheaper
        let full = LshAttention { rounds: 2, chunk: 16 }.cost(1024, 64, 64);
        let cut = LshHamAttention { rounds: 2, chunk: 16, topk: 8 }
            .cost(1024, 64, 64);
        assert!(cut.flops < full.flops);
    }
}
