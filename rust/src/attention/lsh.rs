//! ct-contract: bit-exact
//!
//! Reformer-style LSH attention baseline: shared-QK, angular LSH
//! bucketing, chunked local attention, rounds combined with logsumexp
//! weights.
//!
//! Positions are processed in `chunk`-sized blocks of the bucket-sorted
//! order; the final block may be **ragged** (`N % chunk != 0` is fine:
//! there are `ceil(N / chunk)` blocks and the last is simply smaller),
//! which is what lets valid-length masking hand this kernel arbitrary
//! unpadded lengths.  For chunk-divisible `N` the blocking — and
//! therefore every output bit — is identical to the historical
//! divisible-only path.

use crate::exec::ExecCtx;
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, Matrix};

use super::{AttentionKernel, AttnProblem, Cost};

/// Shared-QK chunked LSH attention; rounds combined with logsumexp weights.
pub fn reformer_attention(x: &Matrix, v: &Matrix, rounds: usize,
                          chunk: usize, rng: &mut Xoshiro256) -> Matrix {
    reformer_attention_ctx(x, v, rounds, chunk, rng,
                           &ExecCtx::sequential())
}

/// [`reformer_attention`] with the per-position bucketing argmax
/// partitioned over the ctx pool (each position's bucket is a pure
/// function of its row and the round's rotation, so the parallel
/// assignment is bit-identical to the sequential loop).
pub fn reformer_attention_ctx(x: &Matrix, v: &Matrix, rounds: usize,
                              chunk: usize, rng: &mut Xoshiro256,
                              ctx: &ExecCtx) -> Matrix {
    let n = x.rows;
    assert!(chunk >= 1, "chunk must be >= 1");
    if n == 0 {
        return Matrix::zeros(0, v.cols);
    }
    let n_buckets = 16usize;
    let scale = 1.0 / (x.cols as f32).sqrt();

    let mut outs: Vec<Matrix> = Vec::with_capacity(rounds);
    let mut lses: Vec<Vec<f32>> = Vec::with_capacity(rounds);

    for _ in 0..rounds {
        // angular LSH: argmax over [xR; -xR]
        let rot = Matrix::randn(n_buckets / 2, x.cols, rng);
        let bucket_of = |i: usize| {
            let (mut best_v, mut best_b) = (f32::NEG_INFINITY, 0usize);
            for b in 0..n_buckets / 2 {
                let h = dot(x.row(i), rot.row(b));
                if h > best_v {
                    best_v = h;
                    best_b = b;
                }
                if -h > best_v {
                    best_v = -h;
                    best_b = b + n_buckets / 2;
                }
            }
            best_b
        };
        let buckets: Vec<usize> = ctx.map_indexed(n, bucket_of);
        // stable sort by bucket
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (buckets[i], i));

        let mut out = Matrix::zeros(n, v.cols);
        let mut lse = vec![f32::NEG_INFINITY; n];
        // chunk boundaries: full blocks plus a ragged final block
        let n_chunks = n.div_ceil(chunk);
        let bounds = |c: usize| (c * chunk, ((c + 1) * chunk).min(n));
        for cidx in 0..n_chunks {
            let prev = (cidx + n_chunks - 1) % n_chunks;
            let (p0, p1) = bounds(prev);
            let (c0, c1) = bounds(cidx);
            // candidate keys: previous chunk ++ own chunk
            let cand: Vec<usize> = order[p0..p1]
                .iter()
                .chain(&order[c0..c1])
                .copied()
                .collect();
            for &qi in &order[c0..c1] {
                let mut logits = Vec::with_capacity(cand.len());
                for &kj in &cand {
                    let l = if buckets[kj] != buckets[qi] {
                        f32::NEG_INFINITY
                    } else if kj == qi {
                        -5e8 // self only as a fallback
                    } else {
                        dot(x.row(qi), x.row(kj)) * scale
                    };
                    logits.push(l);
                }
                let m = logits.iter().copied().fold(f32::NEG_INFINITY,
                                                    f32::max);
                let mut sum = 0f32;
                for l in &mut logits {
                    *l = (*l - m).exp();
                    // ct-lint: allow(det-float-accum, reason = "softmax normaliser accumulated over a bucket in ascending key order; the elementary order is the pinned contract")
                    sum += *l;
                }
                lse[qi] = m + sum.max(1e-30).ln();
                let inv = 1.0 / sum.max(1e-30);
                let orow = out.row_mut(qi);
                for (slot, &kj) in cand.iter().enumerate() {
                    if logits[slot] > 0.0 {
                        axpy(orow, logits[slot] * inv, v.row(kj));
                    }
                }
            }
        }
        outs.push(out);
        lses.push(lse);
    }

    // combine rounds: softmax over per-position lse
    let mut combined = Matrix::zeros(n, v.cols);
    for i in 0..n {
        let m = (0..rounds)
            .map(|r| lses[r][i])
            .fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f32> = (0..rounds).map(|r| (lses[r][i] - m).exp())
            .collect();
        // ct-lint: allow(det-float-reduce, reason = "round-weight sum over the fixed rounds vector, ascending; reduction order is pinned")
        let tot: f32 = ws.iter().sum();
        let orow = combined.row_mut(i);
        for r in 0..rounds {
            axpy(orow, ws[r] / tot.max(1e-30), outs[r].row(i));
        }
    }
    combined
}

/// Reformer-style LSH attention kernel (shared QK; `k` input is unused).
#[derive(Debug, Clone, Copy)]
pub struct LshAttention {
    pub rounds: usize,
    pub chunk: usize,
}

impl AttentionKernel for LshAttention {
    fn name(&self) -> String {
        format!("lsh-{}", self.rounds)
    }

    /// Masking = solving the valid-prefix sub-problem: bucketing,
    /// sorting and chunking see only the valid positions (the ragged
    /// final chunk absorbs any length), and the per-round rotation
    /// draws depend only on the head dim — so the masked run is
    /// bit-identical to the unpadded run.
    ///
    /// A `query_span` is honored by computing the full valid solve and
    /// emitting only the span rows (exact by construction): every
    /// position participates in the joint bucket sort and chunk
    /// layout, so there is no cheaper exact span for this family — the
    /// KV cache still removes the per-step history re-upload, but not
    /// the recompute.
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "lsh does not support causal attention");
        let (q, _, v) = p.valid_qkv();
        let out = reformer_attention_ctx(&q, &v, self.rounds, self.chunk,
                                         rng, ctx);
        if p.is_spanned() {
            return p.restore_span(out.row_span(p.span_start(), out.rows));
        }
        p.restore_rows(out)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (r, c) = (self.rounds as u64, self.chunk as u64);
        Cost {
            flops: r * n64 * 2 * c * (dk64 + dv64)
                + r * n64 * dk64 * 8,
            bytes: 4 * r * n64 * 2 * c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ragged_final_chunk_is_well_defined() {
        // N = 2·chunk + tail: there are ceil(N/chunk) blocks, the last
        // one smaller — output stays finite and correctly shaped
        let mut rng = Xoshiro256::new(1);
        let x = Matrix::randn(41, 8, &mut rng);
        let v = Matrix::randn(41, 8, &mut rng);
        let out = reformer_attention(&x, &v, 2, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (41, 8));
        assert!(out.data.iter().all(|f| f.is_finite()));
        // shorter than one chunk: a single ragged block, still defined
        let out = reformer_attention(&x.row_prefix(5), &v.row_prefix(5),
                                     1, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (5, 8));
        assert!(out.data.iter().all(|f| f.is_finite()));
        // empty input short-circuits instead of dividing by zero
        let empty = reformer_attention(&Matrix::zeros(0, 8),
                                       &Matrix::zeros(0, 8), 1, 16,
                                       &mut rng);
        assert_eq!((empty.rows, empty.cols), (0, 8));
    }

    #[test]
    fn identical_inputs_and_rng_streams_are_deterministic() {
        let mut rng = Xoshiro256::new(3);
        let x = Matrix::randn(32, 8, &mut rng);
        let v = Matrix::randn(32, 8, &mut rng);
        let mut r1 = Xoshiro256::new(7);
        let mut r2 = Xoshiro256::new(7);
        let a = reformer_attention(&x, &v, 2, 16, &mut r1);
        let b = reformer_attention(&x, &v, 2, 16, &mut r2);
        assert!(a.bit_identical(&b));
    }
}
