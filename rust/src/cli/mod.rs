//! Declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed getters, defaults and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declared option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {s:?}")),
        }
    }
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {s:?}")),
        }
    }
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {s:?}")),
        }
    }
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// A command spec: parses argv against declared options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>,
               help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default, is_flag: false });
        self
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n  options:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" (default {d})"))
                .unwrap_or_default();
            s += &format!("    --{}{kind}  {}{def}\n", o.name, o.help);
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n{}",
                                           self.usage()))?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        bail!("--{key} is a flag and takes no value");
                    }
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("steps", Some("100"), "optimizer steps")
            .opt("model", None, "manifest model name")
            .flag("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = cmd()
            .parse(&sv(&["--model", "wsj", "--steps=250", "--verbose",
                         "pos1"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("wsj"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 250);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_is_an_error() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
        assert!(cmd().parse(&sv(&["--steps"])).is_err());
        assert!(cmd().parse(&sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn bad_int_reports_key() {
        let a = cmd().parse(&sv(&["--steps", "abc"])).unwrap();
        let err = a.get_usize("steps", 0).unwrap_err().to_string();
        assert!(err.contains("steps"));
    }
}
