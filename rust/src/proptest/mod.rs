//! Mini property-testing harness (the proptest crate is unavailable
//! offline — DESIGN.md §5).  Seeded case generation with first-failure
//! shrinking over the case index: on failure the harness reports the seed
//! and case so the exact input is reproducible.

use crate::prng::Xoshiro256;

#[cfg(test)]
mod attention_props;

/// Run `cases` random checks.  `gen` builds an input from an RNG;
/// `check` returns an error message on violation.
pub fn forall<T: std::fmt::Debug, G, C>(name: &str, seed: u64, cases: usize,
                                        mut gen: G, mut check: C)
where
    G: FnMut(&mut Xoshiro256) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Xoshiro256::new(seed).fold_in(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property {name:?} failed (seed={seed}, case={case}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::prng::Xoshiro256;

    pub fn vec_f32(rng: &mut Xoshiro256, min_len: usize, max_len: usize)
                   -> Vec<f32> {
        let n = min_len + rng.below(max_len - min_len + 1);
        rng.normal_vec(n)
    }

    pub fn vec_i32(rng: &mut Xoshiro256, len: usize, lo: i64, hi: i64)
                   -> Vec<i32> {
        (0..len).map(|_| rng.range(lo, hi) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall("abs is nonneg", 1, 100,
               |rng| rng.normal_f32(),
               |x| if x.abs() >= 0.0 { Ok(()) }
                   else { Err("negative abs".into()) });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall("always fails", 2, 10,
               |rng| rng.next_f32(),
               |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        forall("vec bounds", 3, 50,
               |rng| gen::vec_i32(rng, 20, 5, 9),
               |v| {
                   if v.len() == 20 && v.iter().all(|&x| (5..9).contains(&x))
                   {
                       Ok(())
                   } else {
                       Err(format!("out of bounds: {v:?}"))
                   }
               });
    }
}
