//! Properties of the batched multi-head attention engine and the tiled
//! compute core:
//!
//!  1. **Determinism contract** — `solve_batch` over any pool size is
//!     bit-for-bit identical to the sequential per-slice loop
//!     (`solve_batch_seq`) for every registered kernel family.
//!  2. **Intra-slice determinism** — `AttentionKernel::solve` with a
//!     parallel `ExecCtx` (row-partitioned GEMM, streaming softmax,
//!     clustering, top-k) is bit-for-bit identical to the sequential
//!     ctx, for every kernel family and worker count.
//!  3. **Masking contract** — solving bucket-padded inputs (padding
//!     filled with random garbage, not zeros) with `valid_len` set is
//!     bit-identical to solving the unpadded inputs, for every kernel
//!     family, ragged length and worker count; padded output rows are
//!     exactly zero.  The batched form holds per sequence through
//!     `AttnBatch::lens`.
//!  4. **Blocked GEMM ≡ naive** — the cache-blocked, panel-packed GEMM
//!     (NN and NT) matches the naive i-k-j scalar loop bit for bit on
//!     random shapes, including non-multiples of the tile sizes, for
//!     any worker count.
//!  5. **Row-stochasticity** — clustered attention matrices (plain and
//!     improved) stay probability distributions row-wise.
//!  6. **Gateway determinism on ragged traces** — a live
//!     `ServingGateway` co-batch of ragged lengths (threaded ingress,
//!     deadline batcher, shared pool, intra-slice parallelism on,
//!     masking on) returns, per request, exactly the unpadded
//!     computation of that request.
//!  7. **Span contract** — solving with `query_span = s` emits rows
//!     `s..valid` bit-identical to the spanless solve (zeros outside),
//!     for every kernel family, ragged length, span and worker count.
//!  8. **Decode-cache contract** — a `CachingBackend` session (prefill
//!     + ragged decode steps) produces, at every step, span rows
//!     bit-identical to the full unpadded recompute of the history on
//!     the session's PRNG streams — for every kernel family, worker
//!     count, and across eviction points (a capacity that evicts
//!     mid-session just turns hits into equally-exact misses).  The
//!     clustered families additionally hold it at the re-cluster
//!     threshold boundary (`growth > 1`): re-cluster steps stay exact
//!     and frozen-reuse steps are bit-deterministic across worker
//!     counts.
//!  9. **Sharded fan-out contract** — a `ShardedBackend` over any
//!     number of in-process shard workers is bit-for-bit identical to
//!     `NativeBackend` on the same descriptor, for every kernel
//!     family, shard count, ragged lens and batch/head-axis split
//!     (including B < shards, where the planner splits heads).
//! 10. **Sharded decode contract** — decode sessions routed through a
//!     sharded backend land on their consistent-hash owner every step
//!     (sticky: later steps hit that shard's cache) and every step's
//!     span rows equal the full unpadded recompute of the history.
//! 11. **Lane-composition invariance** — `replay_blocking` over a live
//!     gateway of batch-1 buckets returns byte-identical responses
//!     (outputs *and* metadata) for any client lane count, RNG kernels
//!     included: at batch size 1 every one-shot PRNG stream keys off
//!     batch slot 0 and session streams are slot-independent, so how
//!     requests get composed into batches can never move bits.  This
//!     is the invariant the golden-trace oracle leans on — fixtures
//!     recorded at one lane count must replay bit-exactly at another.
//! 12. **Causal linear ≡ naive kernelized reference** — the linear
//!     family's O(N·D²) prefix-accumulator causal solve equals, bit for
//!     bit, a naive O(N²·D) reference that rebuilds row `i`'s `(S, z)`
//!     from scratch over keys `0..=i` in the pinned elementary order —
//!     across ragged valid lengths, spans and worker counts.
//! 13. **Recurrent decode contract** — a causal linear decode session
//!     through a `CachingBackend` (the O(1) `RecurrentState` cache
//!     path) produces, at every step, span rows bit-identical to the
//!     full causal recompute of the history on the session streams —
//!     across eviction points (a zero-capacity cache turns every hit
//!     into an equally-exact miss) and through a `ShardedBackend` at
//!     shard counts {1, 3}, where sessions stick to their
//!     consistent-hash owner.
//! 14. **Quantized-cache mechanism contract** — with `quant != Off`,
//!     every post-prefill hit step is bit-identical to an oracle that
//!     re-quantizes the raw history *by hand* (one `QuantSeg` per step
//!     boundary, exactly mirroring the panel store) and solves over
//!     the dequantized panels: quantization is deterministic, so the
//!     only thing it may change is the panel bytes, never the solve.
//! 15. **Quantized tolerance contract** — quantized decode stays
//!     within the declared `OutputBits` tolerance of the exact f32
//!     recompute across panel families × eviction points × worker
//!     counts, with per-family bands (smooth families tight; the
//!     discrete families bounded by the convex-hull envelope of the
//!     value rows), and collapses to `OutputBits::Exact` on every
//!     miss step and whenever `quant` is `Off` (the default).
//! 16. **Sharded quantization invariance** — a quantized decode
//!     session through a `ShardedBackend` (workers running i8 caches)
//!     is bit-for-bit the single-host quantized `CachingBackend`
//!     trajectory at shard counts {1, 3}: sharding cannot move bits
//!     even in the tolerance-gated storage mode.

use std::sync::Arc;
use std::time::Duration;

use crate::attention::{clustered_attention_matrix,
                       improved_clustered_attention_matrix, kernel_by_name,
                       kernel_for, solve_batch_seq, AttentionBackend,
                       AttnBatch, AttnProblem, CacheQuant, CacheRef,
                       CachingBackend, KvCache, KvCacheOptions,
                       NativeBackend, SeqOutcome, SessionRef, ShardOptions,
                       ShardedBackend, Variant};
use crate::clustering::{cluster_queries, Clustering};
use crate::coordinator::{pad_batch, replay_blocking, synthetic_decode_trace,
                         synthetic_trace, unpadded_reference, valid_rows,
                         Bucket, GatewayOptions, GatewayShape,
                         ServingGateway};
use crate::exec::{ExecCtx, WorkerPool};
use crate::oracle::OutputBits;
use crate::prng::{session_seed, slice_stream, Xoshiro256};
use crate::proptest::forall;
use crate::tensor::batch::BatchMatrix;
use crate::tensor::quant::QuantPanel;
use crate::tensor::{gemm, Matrix};

/// Small-hyperparameter instances of every kernel family.  The LSH
/// chunk (16) deliberately does *not* divide the ragged lengths the
/// masking property generates — the ragged final chunk must hold.
fn all_variants() -> Vec<Variant> {
    vec![
        Variant::Full,
        Variant::SharedFull,
        Variant::Clustered { clusters: 4, bits: 31, iters: 5 },
        Variant::ImprovedClustered { clusters: 4, bits: 31, iters: 5,
                                     topk: 8 },
        Variant::OracleTop { topk: 8 },
        Variant::Lsh { rounds: 2, chunk: 16 },
        // topk 8 < 2·chunk: the Hamming pre-filter genuinely prunes
        Variant::LshHam { rounds: 2, chunk: 16, topk: 8 },
        Variant::Linear,
    ]
}

#[test]
fn prop_solve_batch_is_bit_identical_to_sequential_loop() {
    forall(
        "solve_batch ≡ per-slice solve, all variants",
        0xBA7C11ED,
        6,
        |rng| {
            let b = 1 + rng.below(2); // 1..=2
            let h = 1 + rng.below(3); // 1..=3
            let n = 32 * (1 + rng.below(2)); // 32 | 64
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = BatchMatrix::randn(b, h, n, d, rng);
            let k = BatchMatrix::randn(b, h, n, d, rng);
            let v = BatchMatrix::randn(b, h, n, d, rng);
            let workers = 2 + rng.below(4); // 2..=5
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            // par_rows = 1 forces the intra-slice compute core parallel
            // on top of the slice-axis parallelism
            let ctx =
                ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let batch = AttnBatch::new(q, k, v, *seed);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let par = kernel.solve_batch(&batch, &ctx);
                let seq = solve_batch_seq(kernel.as_ref(), &batch);
                if !par.bit_identical(&seq) {
                    return Err(format!(
                        "{} diverged from sequential (B={} H={} N={} \
                         workers={workers})",
                        var.name(), q.batch, q.heads, q.rows));
                }
                if (par.batch, par.heads, par.rows, par.cols)
                    != (q.batch, q.heads, q.rows, v.cols)
                {
                    return Err(format!("{} bad output shape", var.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kernel_solve_is_bit_identical_across_exec_ctx() {
    forall(
        "solve(ctx parallel) ≡ solve(ctx sequential), all variants",
        0x1A7A_C0DE,
        5,
        |rng| {
            let n = 32 * (1 + rng.below(3)); // 32 | 64 | 96
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 2 + rng.below(5); // 2..=6
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let seq = ExecCtx::sequential();
            let p = AttnProblem::new(q, k, v);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let mut r1 = Xoshiro256::new(*seed);
                let mut r2 = Xoshiro256::new(*seed);
                let a = kernel.solve(&p, &mut r1, &seq);
                let b = kernel.solve(&p, &mut r2, &par);
                if !a.bit_identical(&b) {
                    return Err(format!(
                        "{} intra-slice parallel diverged (N={} \
                         workers={workers})",
                        var.name(), q.rows));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_padded_solve_is_bit_identical_to_unpadded_solve() {
    forall(
        "solve(padded, valid_len=l) ≡ solve(unpadded), all variants",
        0x3A5C_11ED,
        6,
        |rng| {
            let n = 24 + rng.below(73); // 24..=96, rarely tile-aligned
            let l = 1 + rng.below(n); // 1..=n, any raggedness
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            // the padded buffers are FULLY random — padding rows carry
            // garbage, so any kernel that peeks at them gets caught
            // (zero padding would mask the bug class the contract
            // exists for)
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 1 + rng.below(5); // 1..=5
            let seed = rng.next_u64();
            (q, k, v, l, workers, seed)
        },
        |(q, k, v, l, workers, seed)| {
            let (l, dv) = (*l, v.cols);
            let (qu, ku, vu) =
                (q.row_prefix(l), k.row_prefix(l), v.row_prefix(l));
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                // masked run on the padded buffers, parallel ctx
                let mut r_pad = Xoshiro256::new(*seed);
                let masked = kernel.solve(
                    &AttnProblem::new(q, k, v).with_valid_len(l),
                    &mut r_pad, &par);
                // unpadded run, sequential ctx — one check covers both
                // the masking and the intra-slice determinism contract
                let mut r_ref = Xoshiro256::new(*seed);
                let want = kernel.solve(&AttnProblem::new(&qu, &ku, &vu),
                                        &mut r_ref,
                                        &ExecCtx::sequential());
                if (masked.rows, masked.cols) != (q.rows, dv) {
                    return Err(format!("{} bad masked shape", var.name()));
                }
                if !masked.row_prefix(l).bit_identical(&want) {
                    return Err(format!(
                        "{} masked(N={}, l={l}, workers={workers}) \
                         diverged from the unpadded run",
                        var.name(), q.rows));
                }
                if masked.data[l * dv..].iter().any(|&x| x != 0.0) {
                    return Err(format!(
                        "{} left non-zero padded output rows (N={}, \
                         l={l})", var.name(), q.rows));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_lens_mask_each_sequence_like_its_unpadded_run() {
    forall(
        "solve_batch(lens) ≡ per-sequence unpadded solves, all variants",
        0x4A66_EDBA,
        4,
        |rng| {
            let b = 2 + rng.below(2); // 2..=3
            let h = 1 + rng.below(2); // 1..=2
            let n = 32 + rng.below(33); // 32..=64
            let d = 8;
            let q = BatchMatrix::randn(b, h, n, d, rng);
            let k = BatchMatrix::randn(b, h, n, d, rng);
            let v = BatchMatrix::randn(b, h, n, d, rng);
            let lens: Vec<usize> =
                (0..b).map(|_| 1 + rng.below(n)).collect();
            let workers = 2 + rng.below(3); // 2..=4
            let seed = rng.next_u64();
            (q, k, v, lens, workers, seed)
        },
        |(q, k, v, lens, workers, seed)| {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let dv = v.cols;
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let batch =
                    AttnBatch::new(q, k, v, *seed).with_lens(lens);
                let out = kernel.solve_batch(&batch, &ctx);
                for s in 0..q.slices() {
                    let l = lens[s / q.heads];
                    // the unpadded single-slice run on this slice's
                    // PRNG stream is the ground truth
                    let mut rng_s =
                        crate::prng::slice_stream(*seed, s as u64);
                    let (qs, ks, vs) =
                        (q.slice_valid(s, l), k.slice_valid(s, l),
                         v.slice_valid(s, l));
                    let want = kernel.solve(
                        &AttnProblem::new(&qs, &ks, &vs), &mut rng_s,
                        &ExecCtx::sequential());
                    let got = out.slice_matrix(s);
                    let bits_match = got.data[..l * dv]
                        .iter()
                        .zip(&want.data)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    if !bits_match {
                        return Err(format!(
                            "{} slice {s} (len {l}) diverged from its \
                             unpadded run", var.name()));
                    }
                    if got.data[l * dv..].iter().any(|&x| x != 0.0) {
                        return Err(format!(
                            "{} slice {s} padded rows not zero",
                            var.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spanned_solve_is_bit_identical_to_the_spanless_solve() {
    forall(
        "solve(valid_len=l, query_span=s) ≡ rows s..l of solve(l), all \
         variants",
        0x5DA2_11ED,
        5,
        |rng| {
            let n = 24 + rng.below(49); // 24..=72
            let l = 2 + rng.below(n - 1); // 2..=n
            let s = rng.below(l); // 0..l
            let d = 8;
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 1 + rng.below(4); // 1..=4
            let seed = rng.next_u64();
            (q, k, v, l, s, workers, seed)
        },
        |(q, k, v, l, s, workers, seed)| {
            let (l, s, dv) = (*l, *s, v.cols);
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let mut r_span = Xoshiro256::new(*seed);
                let spanned = kernel.solve(
                    &AttnProblem::new(q, k, v)
                        .with_valid_len(l)
                        .with_query_span(s),
                    &mut r_span, &par);
                let mut r_ref = Xoshiro256::new(*seed);
                let want = kernel.solve(
                    &AttnProblem::new(q, k, v).with_valid_len(l),
                    &mut r_ref, &ExecCtx::sequential());
                if !spanned
                    .row_span(s, l)
                    .bit_identical(&want.row_span(s, l))
                {
                    return Err(format!(
                        "{} span rows (N={}, l={l}, s={s}, \
                         workers={workers}) diverged from the spanless \
                         solve", var.name(), q.rows));
                }
                if spanned.data[..s * dv].iter().any(|&x| x != 0.0)
                    || spanned.data[l * dv..].iter().any(|&x| x != 0.0)
                {
                    return Err(format!(
                        "{} non-zero rows outside the span", var.name()));
                }
            }
            Ok(())
        },
    );
}

/// One decode session's shape: full history tensors plus the step
/// lengths (prefill first).
type DecodeCase = (BatchMatrix, BatchMatrix, BatchMatrix, Vec<usize>,
                   usize, usize, u64);

fn decode_prefix(t: &BatchMatrix, len: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(1, t.heads, len, t.cols);
    for h in 0..t.heads {
        out.slice_mut(h)
            .copy_from_slice(&t.view(h).data[..len * t.cols]);
    }
    out
}

/// Run one session through a fresh `CachingBackend`; returns, per step,
/// the concatenated per-head span rows and the outcome.
#[allow(clippy::too_many_arguments)]
fn run_session(kernel: &str, growth: f64, capacity: usize,
               quant: CacheQuant, q: &BatchMatrix, k: &BatchMatrix,
               v: &BatchMatrix, lens: &[usize], workers: usize, seed: u64,
               sid: u64, causal: bool) -> Vec<(Vec<f32>, SeqOutcome)> {
    let cache = Arc::new(KvCache::new(KvCacheOptions {
        capacity_rows: capacity,
        growth,
        quant,
    }));
    let backend = CachingBackend::native(kernel, cache).expect("kernel");
    let ctx = if workers <= 1 {
        ExecCtx::sequential()
    } else {
        ExecCtx::with_par_rows(WorkerPool::new(workers), 1)
    };
    let heads = q.heads;
    let dv = v.cols;
    let mut steps = Vec::new();
    let mut span = 0usize;
    for &len in lens {
        let (qp, kp, vp) =
            (decode_prefix(q, len), decode_prefix(k, len),
             decode_prefix(v, len));
        let blens = [len];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: sid, generation: 0 },
            span_start: span,
        })];
        let batch = AttnBatch::new(&qp, &kp, &vp, seed)
            .with_lens(&blens)
            .with_sessions(&sessions)
            .with_causal(causal);
        let (out, rep) = backend.execute_with_report(&batch, &ctx);
        let mut rows = Vec::with_capacity(heads * (len - span) * dv);
        for h in 0..heads {
            rows.extend_from_slice(
                &out.view(h).data[span * dv..len * dv]);
        }
        steps.push((rows, rep[0]));
        span = len;
    }
    steps
}

/// The decode oracle: per head, the full unpadded recompute of the
/// history on the session streams, sliced to the span.
fn recompute_span(kernel: &str, q: &BatchMatrix, k: &BatchMatrix,
                  v: &BatchMatrix, len: usize, span: usize, seed: u64,
                  sid: u64) -> Vec<f32> {
    let kern = kernel_by_name(kernel).expect("kernel");
    let seed2 = session_seed(seed, sid);
    let dv = v.cols;
    let mut rows = Vec::new();
    for h in 0..q.heads {
        let (qh, kh, vh) = (q.slice_valid(h, len), k.slice_valid(h, len),
                            v.slice_valid(h, len));
        let mut rng = slice_stream(seed2, h as u64);
        let o = kern.solve(&AttnProblem::new(&qh, &kh, &vh), &mut rng,
                           &ExecCtx::sequential());
        rows.extend_from_slice(&o.data[span * dv..len * dv]);
    }
    rows
}

/// The causal decode oracle: per head, the full *causal* recompute of
/// the history on the session streams, sliced to the span.
fn recompute_causal_span(kernel: &str, q: &BatchMatrix, k: &BatchMatrix,
                         v: &BatchMatrix, len: usize, span: usize,
                         seed: u64, sid: u64) -> Vec<f32> {
    let kern = kernel_by_name(kernel).expect("kernel");
    let seed2 = session_seed(seed, sid);
    let dv = v.cols;
    let mut rows = Vec::new();
    for h in 0..q.heads {
        let (qh, kh, vh) = (q.slice_valid(h, len), k.slice_valid(h, len),
                            v.slice_valid(h, len));
        let mut rng = slice_stream(seed2, h as u64);
        let o = kern.solve(
            &AttnProblem::new(&qh, &kh, &vh).with_causal(true), &mut rng,
            &ExecCtx::sequential());
        rows.extend_from_slice(&o.data[span * dv..len * dv]);
    }
    rows
}

fn same_bits(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_cached_decode_is_bit_identical_to_full_recompute() {
    let families = ["full", "shared-full", "oracle-top-4", "clustered-3",
                    "i-clustered-3", "lsh-1", "linear"];
    forall(
        "CachingBackend decode ≡ full unpadded recompute, all families, \
         ragged histories × eviction points × worker counts",
        0xDEC0_DE01,
        4,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 6 + rng.below(15); // 6..=20
            let steps = 1 + rng.below(3); // 1..=3
            let mut lens = vec![prefill];
            for _ in 0..steps {
                lens.push(lens.last().unwrap() + 1 + rng.below(6));
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            // capacity: unbounded, or exactly the prefill so the first
            // decode append evicts mid-session (later steps miss — and
            // must stay exact)
            let capacity =
                if rng.coin(0.5) { usize::MAX } else { prefill };
            let workers = 1 + rng.below(4); // 1..=4
            let seed = rng.next_u64();
            (q, k, v, lens, capacity, workers, seed)
        },
        |case: &DecodeCase| {
            let (q, k, v, lens, capacity, workers, seed) = case;
            for kernel in families {
                let steps = run_session(kernel, 1.0, *capacity,
                                        CacheQuant::Off, q, k, v, lens,
                                        *workers, *seed, 77, false);
                let mut span = 0usize;
                for (i, ((rows, outcome), &len)) in
                    steps.iter().zip(lens).enumerate()
                {
                    let want = recompute_span(kernel, q, k, v, len, span,
                                              *seed, 77);
                    if !same_bits(rows, &want) {
                        return Err(format!(
                            "{kernel}: step {i} (span {span}..{len}, \
                             cap {capacity}, workers {workers}) \
                             diverged from the full recompute"));
                    }
                    if i == 0
                        && !matches!(outcome, SeqOutcome::Miss { .. })
                    {
                        return Err(format!(
                            "{kernel}: prefill reported {outcome:?}"));
                    }
                    if i > 0
                        && *capacity == usize::MAX
                        && !matches!(outcome, SeqOutcome::Hit { .. })
                    {
                        return Err(format!(
                            "{kernel}: unbounded-cache step {i} \
                             reported {outcome:?}"));
                    }
                    span = len;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_recluster_threshold_keeps_exact_steps_exact() {
    // growth > 1: frozen-reuse steps are approximate by design, but
    // (a) re-cluster and miss steps stay bit-identical to the full
    // recompute — including the step that crosses the boundary — and
    // (b) the whole trajectory is bit-deterministic across worker
    // counts
    forall(
        "clustered families at the re-cluster threshold boundary",
        0xDEC0_DE02,
        3,
        |rng| {
            let prefill = 8 + rng.below(9); // 8..=16
            let growth = 1.25 + 0.5 * rng.next_f64(); // 1.25..1.75
            // step lens that straddle the threshold: two +1 steps (the
            // first hit re-clusters at prefill+1, the next stays under
            // growth·(prefill+1), so it must reuse), then a jump past
            // the threshold that must re-cluster
            let lens = vec![prefill, prefill + 1, prefill + 2,
                            (prefill as f64 * growth) as usize + 4
                                + rng.below(4)];
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, 2, total, 8, rng);
            let k = BatchMatrix::randn(1, 2, total, 8, rng);
            let v = BatchMatrix::randn(1, 2, total, 8, rng);
            let seed = rng.next_u64();
            (q, k, v, lens, growth, seed)
        },
        |(q, k, v, lens, growth, seed)| {
            for kernel in ["clustered-3", "i-clustered-3"] {
                let a = run_session(kernel, *growth, usize::MAX,
                                    CacheQuant::Off, q, k, v, lens, 1,
                                    *seed, 5, false);
                let b = run_session(kernel, *growth, usize::MAX,
                                    CacheQuant::Off, q, k, v, lens, 3,
                                    *seed, 5, false);
                let mut span = 0usize;
                let mut saw_reuse = false;
                for (i, (((rows_a, out_a), (rows_b, out_b)), &len)) in
                    a.iter().zip(&b).zip(lens).enumerate()
                {
                    if out_a != out_b || !same_bits(rows_a, rows_b) {
                        return Err(format!(
                            "{kernel}: step {i} not deterministic \
                             across worker counts ({out_a:?} vs \
                             {out_b:?})"));
                    }
                    let exact = matches!(
                        out_a,
                        SeqOutcome::Miss { .. }
                            | SeqOutcome::Hit { reclustered: true, .. });
                    saw_reuse |= matches!(
                        out_a,
                        SeqOutcome::Hit { reclustered: false, .. });
                    if exact {
                        let want = recompute_span(kernel, q, k, v, len,
                                                  span, *seed, 5);
                        if !same_bits(rows_a, &want) {
                            return Err(format!(
                                "{kernel}: exact step {i} (span \
                                 {span}..{len}) diverged from the full \
                                 recompute"));
                        }
                    }
                    span = len;
                }
                if !saw_reuse {
                    return Err(format!(
                        "{kernel}: growth {growth} produced no \
                         frozen-reuse step — boundary untested"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_gemm_is_bit_identical_to_naive() {
    forall(
        "blocked GEMM ≡ naive i-k-j loop, NN and NT, ragged shapes",
        0x6E33_1B1D,
        10,
        |rng| {
            // spans sub-tile, tile-aligned and multi-panel shapes
            let m = 1 + rng.below(70);
            let k = 1 + rng.below(2 * gemm::KC + 10);
            let n = 1 + rng.below(40);
            let a = Matrix::randn(m, k, rng);
            let b_nn = Matrix::randn(k, n, rng);
            let b_nt = Matrix::randn(n, k, rng);
            let workers = 1 + rng.below(5); // 1..=5
            (a, b_nn, b_nt, workers)
        },
        |(a, b_nn, b_nt, workers)| {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let nn = gemm::matmul_nn(a, b_nn, &ctx);
            if !nn.bit_identical(&gemm::naive_nn(a, b_nn)) {
                return Err(format!(
                    "NN diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nn.cols));
            }
            let nt = gemm::matmul_nt(a, b_nt, &ctx);
            if !nt.bit_identical(&gemm::naive_nt(a, b_nt)) {
                return Err(format!(
                    "NT diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nt.rows));
            }
            Ok(())
        },
    );
}

/// One gateway request: (q, k, v) blocks plus the valid length.
type GatewayReq = (Vec<f32>, Vec<f32>, Vec<f32>, usize);

#[test]
fn prop_gateway_cobatch_on_ragged_traces_matches_unpadded_compute() {
    const N: usize = 32;
    forall(
        "gateway co-batch ≡ unpadded per-request compute (masked)",
        0x6A7E3A1D,
        4,
        |rng| {
            let kernels = ["full", "clustered-4", "i-clustered-4", "lsh-1"];
            let kernel = kernels[rng.below(kernels.len())].to_string();
            let shape =
                GatewayShape { heads: 1 + rng.below(2), dk: 8, dv: 8 };
            let n_req = 2 + rng.below(2); // 2..=3
            let reqs: Vec<GatewayReq> = (0..n_req)
                .map(|_| {
                    let len = 1 + rng.below(N); // 1..=N, ragged
                    (rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.v_len(len)),
                     len)
                })
                .collect();
            let workers = 2 + rng.below(3); // 2..=4
            let seed = rng.next_u64();
            (kernel, shape, reqs, workers, seed)
        },
        |(kernel, shape, reqs, workers, seed)| {
            let gw = ServingGateway::start(
                *shape,
                vec![Bucket::native(kernel.clone(), N, reqs.len())],
                GatewayOptions {
                    // the size trigger must form the batch, not the clock
                    max_wait: Duration::from_secs(10),
                    queue_capacity: reqs.len() + 1,
                    workers: *workers,
                    seed: *seed,
                    route_up: false,
                    // exercise intra-slice parallelism on the live path
                    par_rows: 1,
                    ..GatewayOptions::default()
                },
            )
            .map_err(|e| format!("gateway start: {e}"))?;
            let rxs: Vec<_> = reqs
                .iter()
                .map(|(q, k, v, len)| {
                    gw.submit_blocking(q.clone(), k.clone(), v.clone(),
                                       *len)
                        .expect("submit")
                })
                .collect();
            let responses: Vec<_> = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(30))
                            .expect("gateway reply"))
                .collect();

            // reference 1: the sequential loop over the identically
            // padded descriptor (lens attached)
            let blocks = |sel: fn(&GatewayReq) -> (&[f32], usize)| {
                reqs.iter().map(sel).collect::<Vec<_>>()
            };
            let q = pad_batch(&blocks(|r| (&r.0, r.3)), shape.heads, N,
                              shape.dk);
            let k = pad_batch(&blocks(|r| (&r.1, r.3)), shape.heads, N,
                              shape.dk);
            let v = pad_batch(&blocks(|r| (&r.2, r.3)), shape.heads, N,
                              shape.dv);
            let lens: Vec<usize> = reqs.iter().map(|r| r.3).collect();
            let resolved = kernel_by_name(kernel).expect("kernel");
            let want = solve_batch_seq(
                resolved.as_ref(),
                &AttnBatch::new(&q, &k, &v, *seed).with_lens(&lens));

            for (slot, (resp, (rq, rk, rv, len))) in
                responses.iter().zip(reqs).enumerate()
            {
                if resp.batch_occupancy != reqs.len() {
                    return Err(format!(
                        "batch composition changed: occupancy {} != {}",
                        resp.batch_occupancy, reqs.len()));
                }
                if !resp.masked {
                    return Err("response not flagged masked".into());
                }
                let want_rows = valid_rows(&want, slot, *len);
                let same = |a: &[f32], b: &[f32]| {
                    a.len() == b.len()
                        && a.iter().zip(b)
                            .all(|(x, y)| x.to_bits() == y.to_bits())
                };
                if !same(&resp.out, &want_rows) {
                    return Err(format!(
                        "{kernel}: slot {slot} (len {len}) diverged from \
                         the sequential masked run"));
                }
                // reference 2: the fully-unpadded computation of this
                // request — no padded tensor anywhere in the reference
                let unpadded = unpadded_reference(
                    resolved.as_ref(), *shape, *seed, slot, rq, rk, rv,
                    *len);
                if !same(&resp.out, &unpadded) {
                    return Err(format!(
                        "{kernel}: slot {slot} (len {len}) diverged from \
                         the unpadded computation"));
                }
            }
            gw.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_clustered_attention_rows_are_row_stochastic() {
    forall(
        "clustered attention rows sum to 1",
        0xC1D5,
        12,
        |rng| {
            let n = 24 + rng.below(25); // 24..=48
            let q = Matrix::randn(n, 8, rng);
            let k = Matrix::randn(n, 8, rng);
            let clusters = 2 + rng.below(5); // 2..=6
            let cl = cluster_queries(&q, clusters, 31, 5, rng);
            (q, k, cl)
        },
        |(q, k, cl): &(Matrix, Matrix, Clustering)| {
            let a_c = clustered_attention_matrix(q, k, cl);
            for r in 0..a_c.rows {
                let s: f32 = a_c.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-5 {
                    return Err(format!("A^c row {r} sums to {s}"));
                }
                if a_c.row(r).iter().any(|&w| w < 0.0) {
                    return Err(format!("A^c row {r} has negative mass"));
                }
            }
            let a_t = improved_clustered_attention_matrix(q, k, cl, 8);
            for r in 0..a_t.rows {
                let s: f32 = a_t.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-4 {
                    return Err(format!("A^t row {r} sums to {s}"));
                }
                if a_t.row(r).iter().any(|&w| w < -1e-6) {
                    return Err(format!("A^t row {r} has negative mass"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_backend_is_bit_identical_to_native() {
    // Property 9.  Resolve kernels by registry NAME on both sides so
    // hyperparameters match exactly; `all_variants()` carries custom
    // bits/iters that a name round-trip would not reproduce.
    let families = ["full", "shared-full", "clustered-3", "i-clustered-3",
                    "oracle-top-4", "lsh-1"];
    forall(
        "ShardedBackend == NativeBackend across families, shard counts, lens",
        0x5AAD_ED01,
        4,
        |rng| {
            let b = 1 + rng.below(4); // 1..=4
            let h = 1 + rng.below(3); // 1..=3
            let n = 24 + rng.below(25); // 24..=48
            let q = BatchMatrix::randn(b, h, n, 8, rng);
            let k = BatchMatrix::randn(b, h, n, 8, rng);
            let v = BatchMatrix::randn(b, h, n, 8, rng);
            let lens: Vec<usize> = (0..b).map(|_| 1 + rng.below(n)).collect();
            let masked = rng.coin(0.5);
            (q, k, v, lens, masked, rng.next_u64())
        },
        |(q, k, v, lens, masked, seed)| {
            let ctx = ExecCtx::sequential();
            for kernel in families {
                let native = NativeBackend::by_name(kernel).expect("kernel");
                let mut batch = AttnBatch::new(q, k, v, *seed);
                if *masked {
                    batch = batch.with_lens(lens);
                }
                let want = native.execute(&batch, &ctx);
                for shards in [1usize, 2, 4] {
                    let sharded = ShardedBackend::in_process(kernel, shards, 1)
                        .expect("kernel");
                    let got = sharded.execute(&batch, &ctx);
                    if !got.bit_identical(&want) {
                        return Err(format!(
                            "{kernel}: {shards} shards diverged from native \
                             (B={} H={} N={} masked={masked})",
                            q.batch, q.heads, q.rows));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_decode_sessions_match_the_full_recompute() {
    // Property 10.  A decode session driven through a sharded backend
    // must (a) produce span rows bit-identical to the unsharded full
    // recompute of its history at every step — routing can never move
    // bits — and (b) actually land on one sticky owner, observable as
    // cache Hits on every post-prefill step.
    forall(
        "sharded decode sessions: sticky owner + exact span rows",
        0x5AAD_ED02,
        3,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 6 + rng.below(11); // 6..=16
            let steps = 1 + rng.below(3); // 1..=3 decode steps
            let mut lens = vec![prefill];
            for _ in 0..steps {
                let grown = lens.last().unwrap() + 1 + rng.below(5);
                lens.push(grown);
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            (q, k, v, lens, rng.next_u64(), rng.next_u64())
        },
        |(q, k, v, lens, sid, seed)| {
            let ctx = ExecCtx::sequential();
            for kernel in ["full", "oracle-top-4", "i-clustered-3"] {
                for shards in [1usize, 3] {
                    let sharded =
                        ShardedBackend::in_process(kernel, shards, 1)
                            .expect("kernel");
                    let mut span = 0usize;
                    for (i, &len) in lens.iter().enumerate() {
                        let qp = decode_prefix(q, len);
                        let kp = decode_prefix(k, len);
                        let vp = decode_prefix(v, len);
                        let blens = [len];
                        let sessions = [Some(SessionRef {
                            cache: CacheRef { session: *sid, generation: 0 },
                            span_start: span,
                        })];
                        let batch = AttnBatch::new(&qp, &kp, &vp, *seed)
                            .with_lens(&blens)
                            .with_sessions(&sessions);
                        let (out, rep) =
                            sharded.execute_with_report(&batch, &ctx);
                        let dv = v.cols;
                        let mut rows = Vec::new();
                        for h in 0..q.heads {
                            rows.extend_from_slice(
                                &out.view(h).data[span * dv..len * dv]);
                        }
                        let want = recompute_span(
                            kernel, q, k, v, len, span, *seed, *sid);
                        if !same_bits(&rows, &want) {
                            return Err(format!(
                                "{kernel}: {shards} shards, step {i} \
                                 (span {span}..{len}) diverged from the \
                                 full recompute"));
                        }
                        if i == 0 && !matches!(rep[0], SeqOutcome::Miss { .. })
                        {
                            return Err(format!(
                                "{kernel}: prefill reported {:?}", rep[0]));
                        }
                        if i > 0 && !matches!(rep[0], SeqOutcome::Hit { .. }) {
                            return Err(format!(
                                "{kernel}: {shards} shards, step {i} \
                                 reported {:?} — session did not stick to \
                                 its owning shard", rep[0]));
                        }
                        span = len;
                    }
                }
            }
            Ok(())
        },
    );
}

/// One lane-invariance case: gateway shape plus the mixed-trace knobs.
type LaneCase = (GatewayShape, usize, usize, usize, usize, usize, u64);

#[test]
fn prop_gateway_replay_is_invariant_to_client_lane_count() {
    // Property 11.  Batch-1 buckets are the precondition: at larger
    // batch sizes the slot a one-shot request lands in feeds its PRNG
    // stream, so batch composition legitimately moves bits for the
    // randomised kernels.  The oracle harness records fixtures under
    // exactly this configuration (and replays them at a *different*
    // lane count), so this property is its soundness proof.
    forall(
        "replay_blocking(lanes ∈ {1,2,8}) byte-identical on batch-1 \
         buckets, one-shots + decode sessions, RNG kernel included",
        0x7A9E_5111,
        3,
        |rng| {
            let shape =
                GatewayShape { heads: 1 + rng.below(2), dk: 8, dv: 8 };
            let oneshots = 4 + rng.below(4); // 4..=7
            let prefill = 5 + rng.below(6); // 5..=10
            let steps = 1 + rng.below(2); // 1..=2
            let step_len = 1 + rng.below(3); // 1..=3, total ≤ 16
            let sessions = 2 + rng.below(2); // 2..=3
            (shape, oneshots, prefill, steps, step_len, sessions,
             rng.next_u64())
        },
        |case: &LaneCase| {
            let (shape, oneshots, prefill, steps, step_len, sessions,
                 seed) = *case;
            for kernel in ["i-clustered-4", "full"] {
                // one mixed trace per kernel: one-shots and session
                // steps interleaved (session step order is preserved,
                // which replay_blocking's lane pinning relies on)
                let a = synthetic_trace(shape, 2, 24, oneshots, seed);
                let b = synthetic_decode_trace(
                    shape, prefill, steps, step_len, sessions,
                    seed ^ 0x9E37_79B9);
                let mut trace = Vec::with_capacity(a.len() + b.len());
                let (mut a, mut b) = (a.into_iter(), b.into_iter());
                loop {
                    match (a.next(), b.next()) {
                        (None, None) => break,
                        (x, y) => {
                            trace.extend(x);
                            trace.extend(y);
                        }
                    }
                }
                let mut runs = Vec::new();
                for clients in [1usize, 2, 8] {
                    let gw = ServingGateway::start(
                        shape,
                        vec![Bucket::native(kernel, 16, 1),
                             Bucket::native(kernel, 32, 1)],
                        GatewayOptions {
                            max_wait: Duration::from_millis(1),
                            seed,
                            ..GatewayOptions::default()
                        },
                    )
                    .map_err(|e| format!("gateway start: {e}"))?;
                    let resp = replay_blocking(&gw, trace.clone(), clients);
                    gw.shutdown();
                    runs.push((clients, resp));
                }
                let (_, base) = &runs[0];
                for (clients, resp) in &runs[1..] {
                    for (i, (got, want)) in
                        resp.iter().zip(base.iter()).enumerate()
                    {
                        if !same_bits(&got.out, &want.out) {
                            return Err(format!(
                                "{kernel}: item {i} output bits moved \
                                 between 1 and {clients} lanes"));
                        }
                        let meta = |r: &crate::coordinator::GatewayResponse| {
                            (r.len, r.span_start, r.session, r.cache_hit,
                             r.bucket_seq_len, r.masked)
                        };
                        if meta(got) != meta(want) {
                            return Err(format!(
                                "{kernel}: item {i} metadata changed \
                                 between 1 and {clients} lanes ({:?} vs \
                                 {:?})", meta(got), meta(want)));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_causal_linear_solve_matches_the_naive_quadratic_reference() {
    // Property 12.  The reference rebuilds row i's (S, z) from zero
    // over keys 0..=i with plain scalar loops in the pinned elementary
    // order (`a` ascending, `c` ascending within `a`, then the
    // `1/den.max(1e-30)` emit) — an O(N²·D) computation sharing no code
    // path with the O(N·D²) prefix-accumulator solve, yet required to
    // match it bit for bit.
    use crate::attention::linear::feature_map;
    forall(
        "causal linear solve ≡ naive per-row kernelized reference, \
         ragged lens × spans × worker counts",
        0x11EA_C001,
        6,
        |rng| {
            let n = 16 + rng.below(49); // 16..=64
            let l = 1 + rng.below(n); // 1..=n, ragged
            let s = rng.below(l); // 0..l
            let dk = 4 + rng.below(9); // 4..=12
            let dv = 4 + rng.below(9); // 4..=12
            // fully random padding rows — a causal solve that peeks
            // past the valid prefix gets caught
            let q = Matrix::randn(n, dk, rng);
            let k = Matrix::randn(n, dk, rng);
            let v = Matrix::randn(n, dv, rng);
            let workers = 1 + rng.below(4); // 1..=4
            (q, k, v, l, s, workers)
        },
        |(q, k, v, l, s, workers)| {
            let (l, s, dk, dv) = (*l, *s, q.cols, v.cols);
            let ctx = if *workers <= 1 {
                ExecCtx::sequential()
            } else {
                ExecCtx::with_par_rows(WorkerPool::new(*workers), 1)
            };
            let kernel = kernel_by_name("linear").expect("registered");
            let mut rng_k = Xoshiro256::new(9);
            let got = kernel.solve(
                &AttnProblem::new(q, k, v)
                    .with_valid_len(l)
                    .with_query_span(s)
                    .with_causal(true),
                &mut rng_k, &ctx);
            for i in s..l {
                let mut sm = vec![0.0f32; dk * dv];
                let mut z = vec![0.0f32; dk];
                for j in 0..=i {
                    let (kj, vj) = (k.row(j), v.row(j));
                    for a in 0..dk {
                        let f = feature_map(kj[a]);
                        z[a] += f;
                        for c in 0..dv {
                            sm[a * dv + c] += f * vj[c];
                        }
                    }
                }
                let qi = q.row(i);
                let mut den = 0.0f32;
                let mut want = vec![0.0f32; dv];
                for a in 0..dk {
                    let f = feature_map(qi[a]);
                    den += f * z[a];
                    for c in 0..dv {
                        want[c] += f * sm[a * dv + c];
                    }
                }
                let inv = 1.0 / den.max(1e-30);
                for w in want.iter_mut() {
                    *w *= inv;
                }
                if !same_bits(&got.data[i * dv..(i + 1) * dv], &want) {
                    return Err(format!(
                        "row {i} (N={}, l={l}, s={s}, dk={dk}, dv={dv}, \
                         workers={workers}) diverged from the naive \
                         reference", q.rows));
                }
            }
            if got.data[..s * dv].iter().any(|&x| x != 0.0)
                || got.data[l * dv..].iter().any(|&x| x != 0.0)
            {
                return Err(format!(
                    "non-zero rows outside the span (l={l}, s={s})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_recurrent_decode_matches_the_full_causal_recompute() {
    // Property 13.  The O(1) recurrent-state cache path: a causal
    // linear decode session must reproduce the full causal recompute of
    // its history bit-for-bit at every step — when the state is pinned
    // (unbounded cache: post-prefill steps Hit and only touch the
    // accumulator), when it never is (zero capacity: every step Misses
    // and replays the prefix), and through a sharded backend at shard
    // counts {1, 3}, where the session sticks to its consistent-hash
    // owner.
    forall(
        "linear causal decode ≡ full causal recompute, eviction points \
         × worker counts × shard counts",
        0xDEC0_DE03,
        4,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 6 + rng.below(11); // 6..=16
            let steps = 1 + rng.below(3); // 1..=3 decode steps
            let mut lens = vec![prefill];
            for _ in 0..steps {
                lens.push(lens.last().unwrap() + 1 + rng.below(5));
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            // a recurrent entry's charge is constant, so it never
            // self-evicts mid-session — the eviction point to exercise
            // is capacity 0, where the state is never pinned at all
            let capacity =
                if rng.coin(0.5) { usize::MAX } else { 0 };
            let workers = 1 + rng.below(3); // 1..=3
            (q, k, v, lens, capacity, workers, rng.next_u64())
        },
        |case: &DecodeCase| {
            let (q, k, v, lens, capacity, workers, seed) = case;
            // single-host CachingBackend across the eviction point
            let steps = run_session("linear", 1.0, *capacity,
                                    CacheQuant::Off, q, k, v, lens,
                                    *workers, *seed, 91, true);
            let mut span = 0usize;
            for (i, ((rows, outcome), &len)) in
                steps.iter().zip(lens).enumerate()
            {
                let want = recompute_causal_span("linear", q, k, v, len,
                                                 span, *seed, 91);
                if !same_bits(rows, &want) {
                    return Err(format!(
                        "step {i} (span {span}..{len}, cap {capacity}, \
                         workers {workers}) diverged from the full \
                         causal recompute"));
                }
                let want_hit = i > 0 && *capacity == usize::MAX;
                if want_hit != matches!(outcome, SeqOutcome::Hit { .. }) {
                    return Err(format!(
                        "step {i} (cap {capacity}) reported {outcome:?}"));
                }
                span = len;
            }
            // sharded: the recurrent path holds across shard counts and
            // the session sticks to one owner (post-prefill Hits)
            let ctx = ExecCtx::sequential();
            for shards in [1usize, 3] {
                let sharded =
                    ShardedBackend::in_process("linear", shards, 1)
                        .expect("kernel");
                let mut span = 0usize;
                for (i, &len) in lens.iter().enumerate() {
                    let qp = decode_prefix(q, len);
                    let kp = decode_prefix(k, len);
                    let vp = decode_prefix(v, len);
                    let blens = [len];
                    let sessions = [Some(SessionRef {
                        cache: CacheRef { session: 91, generation: 0 },
                        span_start: span,
                    })];
                    let batch = AttnBatch::new(&qp, &kp, &vp, *seed)
                        .with_lens(&blens)
                        .with_sessions(&sessions)
                        .with_causal(true);
                    let (out, rep) =
                        sharded.execute_with_report(&batch, &ctx);
                    let dv = v.cols;
                    let mut rows = Vec::new();
                    for h in 0..q.heads {
                        rows.extend_from_slice(
                            &out.view(h).data[span * dv..len * dv]);
                    }
                    let want = recompute_causal_span(
                        "linear", q, k, v, len, span, *seed, 91);
                    if !same_bits(&rows, &want) {
                        return Err(format!(
                            "{shards} shards, step {i} (span \
                             {span}..{len}) diverged from the full \
                             causal recompute"));
                    }
                    if i == 0
                        && !matches!(rep[0], SeqOutcome::Miss { .. })
                    {
                        return Err(format!(
                            "{shards} shards: prefill reported {:?}",
                            rep[0]));
                    }
                    if i > 0 && !matches!(rep[0], SeqOutcome::Hit { .. })
                    {
                        return Err(format!(
                            "{shards} shards, step {i} reported {:?} — \
                             session did not stick to its owning shard",
                            rep[0]));
                    }
                    span = len;
                }
            }
            Ok(())
        },
    );
}

/// Owned copy of rows `lo..hi` of head `h` — the per-head matrix the
/// cache stores for one populate/append segment.
fn head_rows(t: &BatchMatrix, h: usize, lo: usize, hi: usize) -> Matrix {
    Matrix::from_vec(hi - lo, t.cols,
                     t.view(h).data[lo * t.cols..hi * t.cols].to_vec())
}

/// The hand-built quantized-history oracle input: re-quantize the raw
/// history exactly the way the unbounded panel store does — one
/// [`QuantPanel`] segment per step boundary (the prefill populate,
/// then one append per decode step up to `lens[upto]`) — and hand back
/// the dequantized f32 tensor a hit's solve actually sees.
fn quant_history(t: &BatchMatrix, lens: &[usize], upto: usize,
                 per_head: bool) -> BatchMatrix {
    let len = lens[upto];
    let mut out = BatchMatrix::zeros(1, t.heads, len, t.cols);
    for h in 0..t.heads {
        let mut panel =
            QuantPanel::from_matrix(&head_rows(t, h, 0, lens[0]),
                                    per_head);
        for w in lens[..=upto].windows(2) {
            panel.append(&head_rows(t, h, w[0], w[1]));
        }
        out.slice_mut(h).copy_from_slice(&panel.to_matrix().data);
    }
    out
}

/// One quantized-decode case: history tensors, step lens, workers,
/// batch seed.
type QuantCase = (BatchMatrix, BatchMatrix, BatchMatrix, Vec<usize>,
                  usize, u64);

#[test]
fn prop_quantized_decode_matches_the_hand_quantized_history_oracle() {
    // Property 14.  Quantization is deterministic, so the i8 cache may
    // only change the panel *bytes*, never the solve: every
    // post-prefill hit step must be bit-identical to an oracle that
    // re-quantizes the raw history by hand (one segment per step
    // boundary, mirroring the store) and runs the full unpadded solve
    // over the dequantized panels on the session streams.  The prefill
    // miss computes on the raw f32 request tensors and stays bit-exact
    // even with quantization on.
    let families = ["full", "shared-full", "oracle-top-4", "clustered-3",
                    "i-clustered-3", "lsh-1", "lsh-ham-1"];
    forall(
        "quantized decode ≡ hand-quantized-history oracle, all panel \
         families × i8 modes × worker counts",
        0xDEC0_DE04,
        3,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 6 + rng.below(11); // 6..=16
            let steps = 1 + rng.below(3); // 1..=3
            let mut lens = vec![prefill];
            for _ in 0..steps {
                lens.push(lens.last().unwrap() + 1 + rng.below(5));
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            let workers = 1 + rng.below(3); // 1..=3
            (q, k, v, lens, workers, rng.next_u64())
        },
        |case: &QuantCase| {
            let (q, k, v, lens, workers, seed) = case;
            for kernel in families {
                for (quant, per_head) in
                    [(CacheQuant::I8PerHead, true),
                     (CacheQuant::I8PerPanel, false)]
                {
                    let steps = run_session(kernel, 1.0, usize::MAX,
                                            quant, q, k, v, lens,
                                            *workers, *seed, 41, false);
                    let mut span = 0usize;
                    for (i, ((rows, outcome), &len)) in
                        steps.iter().zip(lens).enumerate()
                    {
                        let want = if i == 0 {
                            recompute_span(kernel, q, k, v, len, 0,
                                           *seed, 41)
                        } else {
                            let qd = quant_history(q, lens, i, per_head);
                            let kd = quant_history(k, lens, i, per_head);
                            let vd = quant_history(v, lens, i, per_head);
                            recompute_span(kernel, &qd, &kd, &vd, len,
                                           span, *seed, 41)
                        };
                        if !same_bits(rows, &want) {
                            return Err(format!(
                                "{kernel} ({quant:?}): step {i} (span \
                                 {span}..{len}, workers {workers}) \
                                 diverged from the hand-quantized \
                                 history oracle"));
                        }
                        let hit = matches!(outcome,
                                           SeqOutcome::Hit { .. });
                        if hit != (i > 0) {
                            return Err(format!(
                                "{kernel} ({quant:?}): step {i} \
                                 reported {outcome:?}"));
                        }
                        span = len;
                    }
                }
            }
            Ok(())
        },
    );
}

/// One tolerance case: history tensors, step lens, the mid-session
/// eviction coin, workers, batch seed.
type QuantTolCase = (BatchMatrix, BatchMatrix, BatchMatrix, Vec<usize>,
                     bool, usize, u64);

#[test]
fn prop_quantized_decode_stays_within_the_declared_tolerance() {
    // Property 15.  The tolerance the policy layer declares
    // (`OutputBits`) actually holds: quantized hit steps stay within a
    // per-family band of the exact f32 recompute, and everything else
    // — every miss step (computed on raw request tensors) and every
    // step with quant Off — collapses to `OutputBits::Exact`.
    //
    // Band rationale: the smooth families (full, shared-full, linear)
    // move continuously with the ≤ scale/2 input perturbation, so a
    // small fixed band suffices.  The discrete families (clustered,
    // i-clustered, oracle-top, lsh, lsh-ham) can flip an assignment /
    // top-k pick / bucket under the same perturbation, swapping one
    // near-convex combination of value rows for another — the sound
    // envelope is the convex-hull diameter `2·max|V|` (both outputs
    // live in `[-max|V|, max|V|]` elementwise), plus slack for the
    // improved-clustered path's ~1e-6 negative mass.
    let smooth = ["full", "shared-full", "linear"];
    let discrete = ["clustered-3", "i-clustered-3", "oracle-top-4",
                    "lsh-1", "lsh-ham-1"];
    forall(
        "quantized decode within declared OutputBits of the exact \
         recompute; Exact on misses and with quant Off",
        0xDEC0_DE05,
        3,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 8 + rng.below(9); // 8..=16
            let steps = 1 + rng.below(2); // 1..=2
            let mut lens = vec![prefill];
            for _ in 0..steps {
                lens.push(lens.last().unwrap() + 1 + rng.below(5));
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            // eviction point: a capacity of exactly the prefill's
            // quantized charge ⌈prefill/4⌉ lets the populate land but
            // makes the first append overflow — the hit that appends
            // is tolerance-gated, every later step misses and must be
            // bit-exact again
            let evict = rng.coin(0.5);
            let workers = 1 + rng.below(3); // 1..=3
            (q, k, v, lens, evict, workers, rng.next_u64())
        },
        |case: &QuantTolCase| {
            let (q, k, v, lens, evict, workers, seed) = case;
            let vmax = f64::from(
                (0..v.slices())
                    .flat_map(|s| v.view(s).data.iter())
                    .fold(0.0f32, |a, &x| f32::max(a, x.abs())));
            let tight = OutputBits::Tolerance { abs_tol: 0.3,
                                                rel_tol: 0.3 };
            let hull = OutputBits::Tolerance {
                abs_tol: 2.0 * vmax + 0.05,
                rel_tol: 0.05,
            };
            let banded = smooth
                .iter()
                .map(|&f| (f, tight))
                .chain(discrete.iter().map(|&f| (f, hull)));
            let capacity = if *evict {
                lens[0].div_ceil(4)
            } else {
                usize::MAX
            };
            for (kernel, band) in banded {
                for quant in [CacheQuant::Off, CacheQuant::I8PerHead,
                              CacheQuant::I8PerPanel]
                {
                    let steps = run_session(kernel, 1.0, capacity, quant,
                                            q, k, v, lens, *workers,
                                            *seed, 57, false);
                    let mut span = 0usize;
                    for (i, ((rows, outcome), &len)) in
                        steps.iter().zip(lens).enumerate()
                    {
                        if !evict
                            && i > 0
                            && !matches!(outcome, SeqOutcome::Hit { .. })
                        {
                            return Err(format!(
                                "{kernel} ({quant:?}): unbounded step \
                                 {i} reported {outcome:?} — the \
                                 tolerance path went unexercised"));
                        }
                        let want = recompute_span(kernel, q, k, v, len,
                                                  span, *seed, 57);
                        let exact = quant == CacheQuant::Off
                            || matches!(outcome,
                                        SeqOutcome::Miss { .. });
                        let bits =
                            if exact { OutputBits::Exact } else { band };
                        for (j, (a, b)) in
                            rows.iter().zip(&want).enumerate()
                        {
                            let err = (f64::from(*a) - f64::from(*b))
                                .abs();
                            if !bits.allows(err, f64::from(*b)) {
                                return Err(format!(
                                    "{kernel} ({quant:?}): step {i} \
                                     element {j} err {err} vs ref {b} \
                                     outside {bits:?} (cap {capacity}, \
                                     workers {workers})"));
                            }
                        }
                        span = len;
                    }
                }
            }
            Ok(())
        },
    );
}

/// One sharded-quantization case: history tensors, step lens, the
/// per-head-mode coin, session id, batch seed.
type ShardQuantCase = (BatchMatrix, BatchMatrix, BatchMatrix, Vec<usize>,
                       bool, u64, u64);

#[test]
fn prop_sharded_quantized_decode_is_bit_identical_to_single_host() {
    // Property 16.  Deterministic quantization means sharding cannot
    // move bits even in the tolerance-gated storage mode: the same
    // decode session through a ShardedBackend whose workers run i8
    // caches reproduces the single-host quantized CachingBackend
    // trajectory — outputs *and* outcomes — at shard counts {1, 3}.
    forall(
        "sharded quantized decode ≡ single-host quantized cache, shard \
         counts {1, 3}",
        0xDEC0_DE06,
        3,
        |rng| {
            let heads = 1 + rng.below(2); // 1..=2
            let prefill = 6 + rng.below(9); // 6..=14
            let steps = 1 + rng.below(2); // 1..=2
            let mut lens = vec![prefill];
            for _ in 0..steps {
                lens.push(lens.last().unwrap() + 1 + rng.below(4));
            }
            let total = *lens.last().unwrap();
            let q = BatchMatrix::randn(1, heads, total, 8, rng);
            let k = BatchMatrix::randn(1, heads, total, 8, rng);
            let v = BatchMatrix::randn(1, heads, total, 8, rng);
            (q, k, v, lens, rng.coin(0.5), rng.next_u64(),
             rng.next_u64())
        },
        |case: &ShardQuantCase| {
            let (q, k, v, lens, per_head, sid, seed) = case;
            let quant = if *per_head {
                CacheQuant::I8PerHead
            } else {
                CacheQuant::I8PerPanel
            };
            let ctx = ExecCtx::sequential();
            for kernel in ["full", "i-clustered-3", "lsh-ham-1"] {
                let base = run_session(kernel, 1.0, usize::MAX, quant, q,
                                       k, v, lens, 1, *seed, *sid,
                                       false);
                for shards in [1usize, 3] {
                    let sharded = ShardedBackend::in_process_with(
                        kernel, shards, 1,
                        ShardOptions { cache_quant: quant,
                                       ..ShardOptions::default() })
                        .expect("kernel");
                    let mut span = 0usize;
                    for (i, &len) in lens.iter().enumerate() {
                        let qp = decode_prefix(q, len);
                        let kp = decode_prefix(k, len);
                        let vp = decode_prefix(v, len);
                        let blens = [len];
                        let sessions = [Some(SessionRef {
                            cache: CacheRef { session: *sid,
                                              generation: 0 },
                            span_start: span,
                        })];
                        let batch = AttnBatch::new(&qp, &kp, &vp, *seed)
                            .with_lens(&blens)
                            .with_sessions(&sessions);
                        let (out, rep) =
                            sharded.execute_with_report(&batch, &ctx);
                        let dv = v.cols;
                        let mut rows = Vec::new();
                        for h in 0..q.heads {
                            rows.extend_from_slice(
                                &out.view(h).data[span * dv..len * dv]);
                        }
                        let (want, want_outcome) = &base[i];
                        if !same_bits(&rows, want) {
                            return Err(format!(
                                "{kernel} ({quant:?}): {shards} shards, \
                                 step {i} (span {span}..{len}) moved \
                                 bits vs the single-host quantized \
                                 cache"));
                        }
                        if rep[0] != *want_outcome {
                            return Err(format!(
                                "{kernel} ({quant:?}): {shards} shards, \
                                 step {i} reported {:?}, single-host \
                                 said {want_outcome:?}", rep[0]));
                        }
                        span = len;
                    }
                }
            }
            Ok(())
        },
    );
}
