//! Properties of the batched multi-head attention engine and the tiled
//! compute core:
//!
//!  1. **Determinism contract** — `solve_batch` over any pool size is
//!     bit-for-bit identical to the sequential per-slice loop
//!     (`solve_batch_seq`) for every registered kernel family.
//!  2. **Intra-slice determinism** — `AttentionKernel::solve` with a
//!     parallel `ExecCtx` (row-partitioned GEMM, streaming softmax,
//!     clustering, top-k) is bit-for-bit identical to the sequential
//!     ctx, for every kernel family and worker count.
//!  3. **Masking contract** — solving bucket-padded inputs (padding
//!     filled with random garbage, not zeros) with `valid_len` set is
//!     bit-identical to solving the unpadded inputs, for every kernel
//!     family, ragged length and worker count; padded output rows are
//!     exactly zero.  The batched form holds per sequence through
//!     `AttnBatch::lens`.
//!  4. **Blocked GEMM ≡ naive** — the cache-blocked, panel-packed GEMM
//!     (NN and NT) matches the naive i-k-j scalar loop bit for bit on
//!     random shapes, including non-multiples of the tile sizes, for
//!     any worker count.
//!  5. **Row-stochasticity** — clustered attention matrices (plain and
//!     improved) stay probability distributions row-wise.
//!  6. **Gateway determinism on ragged traces** — a live
//!     `ServingGateway` co-batch of ragged lengths (threaded ingress,
//!     deadline batcher, shared pool, intra-slice parallelism on,
//!     masking on) returns, per request, exactly the unpadded
//!     computation of that request.

use std::time::Duration;

use crate::attention::{clustered_attention_matrix,
                       improved_clustered_attention_matrix, kernel_by_name,
                       kernel_for, solve_batch_seq, AttnBatch, AttnProblem,
                       Variant};
use crate::clustering::{cluster_queries, Clustering};
use crate::coordinator::{pad_batch, unpadded_reference, valid_rows, Bucket,
                         GatewayOptions, GatewayShape, ServingGateway};
use crate::exec::{ExecCtx, WorkerPool};
use crate::prng::Xoshiro256;
use crate::proptest::forall;
use crate::tensor::batch::BatchMatrix;
use crate::tensor::{gemm, Matrix};

/// Small-hyperparameter instances of every kernel family.  The LSH
/// chunk (16) deliberately does *not* divide the ragged lengths the
/// masking property generates — the ragged final chunk must hold.
fn all_variants() -> Vec<Variant> {
    vec![
        Variant::Full,
        Variant::SharedFull,
        Variant::Clustered { clusters: 4, bits: 31, iters: 5 },
        Variant::ImprovedClustered { clusters: 4, bits: 31, iters: 5,
                                     topk: 8 },
        Variant::OracleTop { topk: 8 },
        Variant::Lsh { rounds: 2, chunk: 16 },
    ]
}

#[test]
fn prop_solve_batch_is_bit_identical_to_sequential_loop() {
    forall(
        "solve_batch ≡ per-slice solve, all variants",
        0xBA7C11ED,
        6,
        |rng| {
            let b = 1 + rng.below(2); // 1..=2
            let h = 1 + rng.below(3); // 1..=3
            let n = 32 * (1 + rng.below(2)); // 32 | 64
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = BatchMatrix::randn(b, h, n, d, rng);
            let k = BatchMatrix::randn(b, h, n, d, rng);
            let v = BatchMatrix::randn(b, h, n, d, rng);
            let workers = 2 + rng.below(4); // 2..=5
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            // par_rows = 1 forces the intra-slice compute core parallel
            // on top of the slice-axis parallelism
            let ctx =
                ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let batch = AttnBatch::new(q, k, v, *seed);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let par = kernel.solve_batch(&batch, &ctx);
                let seq = solve_batch_seq(kernel.as_ref(), &batch);
                if !par.bit_identical(&seq) {
                    return Err(format!(
                        "{} diverged from sequential (B={} H={} N={} \
                         workers={workers})",
                        var.name(), q.batch, q.heads, q.rows));
                }
                if (par.batch, par.heads, par.rows, par.cols)
                    != (q.batch, q.heads, q.rows, v.cols)
                {
                    return Err(format!("{} bad output shape", var.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kernel_solve_is_bit_identical_across_exec_ctx() {
    forall(
        "solve(ctx parallel) ≡ solve(ctx sequential), all variants",
        0x1A7A_C0DE,
        5,
        |rng| {
            let n = 32 * (1 + rng.below(3)); // 32 | 64 | 96
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 2 + rng.below(5); // 2..=6
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let seq = ExecCtx::sequential();
            let p = AttnProblem::new(q, k, v);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let mut r1 = Xoshiro256::new(*seed);
                let mut r2 = Xoshiro256::new(*seed);
                let a = kernel.solve(&p, &mut r1, &seq);
                let b = kernel.solve(&p, &mut r2, &par);
                if !a.bit_identical(&b) {
                    return Err(format!(
                        "{} intra-slice parallel diverged (N={} \
                         workers={workers})",
                        var.name(), q.rows));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_padded_solve_is_bit_identical_to_unpadded_solve() {
    forall(
        "solve(padded, valid_len=l) ≡ solve(unpadded), all variants",
        0x3A5C_11ED,
        6,
        |rng| {
            let n = 24 + rng.below(73); // 24..=96, rarely tile-aligned
            let l = 1 + rng.below(n); // 1..=n, any raggedness
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            // the padded buffers are FULLY random — padding rows carry
            // garbage, so any kernel that peeks at them gets caught
            // (zero padding would mask the bug class the contract
            // exists for)
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 1 + rng.below(5); // 1..=5
            let seed = rng.next_u64();
            (q, k, v, l, workers, seed)
        },
        |(q, k, v, l, workers, seed)| {
            let (l, dv) = (*l, v.cols);
            let (qu, ku, vu) =
                (q.row_prefix(l), k.row_prefix(l), v.row_prefix(l));
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                // masked run on the padded buffers, parallel ctx
                let mut r_pad = Xoshiro256::new(*seed);
                let masked = kernel.solve(
                    &AttnProblem::new(q, k, v).with_valid_len(l),
                    &mut r_pad, &par);
                // unpadded run, sequential ctx — one check covers both
                // the masking and the intra-slice determinism contract
                let mut r_ref = Xoshiro256::new(*seed);
                let want = kernel.solve(&AttnProblem::new(&qu, &ku, &vu),
                                        &mut r_ref,
                                        &ExecCtx::sequential());
                if (masked.rows, masked.cols) != (q.rows, dv) {
                    return Err(format!("{} bad masked shape", var.name()));
                }
                if !masked.row_prefix(l).bit_identical(&want) {
                    return Err(format!(
                        "{} masked(N={}, l={l}, workers={workers}) \
                         diverged from the unpadded run",
                        var.name(), q.rows));
                }
                if masked.data[l * dv..].iter().any(|&x| x != 0.0) {
                    return Err(format!(
                        "{} left non-zero padded output rows (N={}, \
                         l={l})", var.name(), q.rows));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_lens_mask_each_sequence_like_its_unpadded_run() {
    forall(
        "solve_batch(lens) ≡ per-sequence unpadded solves, all variants",
        0x4A66_EDBA,
        4,
        |rng| {
            let b = 2 + rng.below(2); // 2..=3
            let h = 1 + rng.below(2); // 1..=2
            let n = 32 + rng.below(33); // 32..=64
            let d = 8;
            let q = BatchMatrix::randn(b, h, n, d, rng);
            let k = BatchMatrix::randn(b, h, n, d, rng);
            let v = BatchMatrix::randn(b, h, n, d, rng);
            let lens: Vec<usize> =
                (0..b).map(|_| 1 + rng.below(n)).collect();
            let workers = 2 + rng.below(3); // 2..=4
            let seed = rng.next_u64();
            (q, k, v, lens, workers, seed)
        },
        |(q, k, v, lens, workers, seed)| {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let dv = v.cols;
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let batch =
                    AttnBatch::new(q, k, v, *seed).with_lens(lens);
                let out = kernel.solve_batch(&batch, &ctx);
                for s in 0..q.slices() {
                    let l = lens[s / q.heads];
                    // the unpadded single-slice run on this slice's
                    // PRNG stream is the ground truth
                    let mut rng_s =
                        crate::prng::slice_stream(*seed, s as u64);
                    let (qs, ks, vs) =
                        (q.slice_valid(s, l), k.slice_valid(s, l),
                         v.slice_valid(s, l));
                    let want = kernel.solve(
                        &AttnProblem::new(&qs, &ks, &vs), &mut rng_s,
                        &ExecCtx::sequential());
                    let got = out.slice_matrix(s);
                    let bits_match = got.data[..l * dv]
                        .iter()
                        .zip(&want.data)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    if !bits_match {
                        return Err(format!(
                            "{} slice {s} (len {l}) diverged from its \
                             unpadded run", var.name()));
                    }
                    if got.data[l * dv..].iter().any(|&x| x != 0.0) {
                        return Err(format!(
                            "{} slice {s} padded rows not zero",
                            var.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_gemm_is_bit_identical_to_naive() {
    forall(
        "blocked GEMM ≡ naive i-k-j loop, NN and NT, ragged shapes",
        0x6E33_1B1D,
        10,
        |rng| {
            // spans sub-tile, tile-aligned and multi-panel shapes
            let m = 1 + rng.below(70);
            let k = 1 + rng.below(2 * gemm::KC + 10);
            let n = 1 + rng.below(40);
            let a = Matrix::randn(m, k, rng);
            let b_nn = Matrix::randn(k, n, rng);
            let b_nt = Matrix::randn(n, k, rng);
            let workers = 1 + rng.below(5); // 1..=5
            (a, b_nn, b_nt, workers)
        },
        |(a, b_nn, b_nt, workers)| {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let nn = gemm::matmul_nn(a, b_nn, &ctx);
            if !nn.bit_identical(&gemm::naive_nn(a, b_nn)) {
                return Err(format!(
                    "NN diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nn.cols));
            }
            let nt = gemm::matmul_nt(a, b_nt, &ctx);
            if !nt.bit_identical(&gemm::naive_nt(a, b_nt)) {
                return Err(format!(
                    "NT diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nt.rows));
            }
            Ok(())
        },
    );
}

/// One gateway request: (q, k, v) blocks plus the valid length.
type GatewayReq = (Vec<f32>, Vec<f32>, Vec<f32>, usize);

#[test]
fn prop_gateway_cobatch_on_ragged_traces_matches_unpadded_compute() {
    const N: usize = 32;
    forall(
        "gateway co-batch ≡ unpadded per-request compute (masked)",
        0x6A7E3A1D,
        4,
        |rng| {
            let kernels = ["full", "clustered-4", "i-clustered-4", "lsh-1"];
            let kernel = kernels[rng.below(kernels.len())].to_string();
            let shape =
                GatewayShape { heads: 1 + rng.below(2), dk: 8, dv: 8 };
            let n_req = 2 + rng.below(2); // 2..=3
            let reqs: Vec<GatewayReq> = (0..n_req)
                .map(|_| {
                    let len = 1 + rng.below(N); // 1..=N, ragged
                    (rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.v_len(len)),
                     len)
                })
                .collect();
            let workers = 2 + rng.below(3); // 2..=4
            let seed = rng.next_u64();
            (kernel, shape, reqs, workers, seed)
        },
        |(kernel, shape, reqs, workers, seed)| {
            let gw = ServingGateway::start(
                *shape,
                vec![Bucket::native(kernel.clone(), N, reqs.len())],
                GatewayOptions {
                    // the size trigger must form the batch, not the clock
                    max_wait: Duration::from_secs(10),
                    queue_capacity: reqs.len() + 1,
                    workers: *workers,
                    seed: *seed,
                    route_up: false,
                    // exercise intra-slice parallelism on the live path
                    par_rows: 1,
                    mask: true,
                },
            )
            .map_err(|e| format!("gateway start: {e}"))?;
            let rxs: Vec<_> = reqs
                .iter()
                .map(|(q, k, v, len)| {
                    gw.submit_blocking(q.clone(), k.clone(), v.clone(),
                                       *len)
                        .expect("submit")
                })
                .collect();
            let responses: Vec<_> = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(30))
                            .expect("gateway reply"))
                .collect();

            // reference 1: the sequential loop over the identically
            // padded descriptor (lens attached)
            let blocks = |sel: fn(&GatewayReq) -> (&[f32], usize)| {
                reqs.iter().map(sel).collect::<Vec<_>>()
            };
            let q = pad_batch(&blocks(|r| (&r.0, r.3)), shape.heads, N,
                              shape.dk);
            let k = pad_batch(&blocks(|r| (&r.1, r.3)), shape.heads, N,
                              shape.dk);
            let v = pad_batch(&blocks(|r| (&r.2, r.3)), shape.heads, N,
                              shape.dv);
            let lens: Vec<usize> = reqs.iter().map(|r| r.3).collect();
            let resolved = kernel_by_name(kernel).expect("kernel");
            let want = solve_batch_seq(
                resolved.as_ref(),
                &AttnBatch::new(&q, &k, &v, *seed).with_lens(&lens));

            for (slot, (resp, (rq, rk, rv, len))) in
                responses.iter().zip(reqs).enumerate()
            {
                if resp.batch_occupancy != reqs.len() {
                    return Err(format!(
                        "batch composition changed: occupancy {} != {}",
                        resp.batch_occupancy, reqs.len()));
                }
                if !resp.masked {
                    return Err("response not flagged masked".into());
                }
                let want_rows = valid_rows(&want, slot, *len);
                let same = |a: &[f32], b: &[f32]| {
                    a.len() == b.len()
                        && a.iter().zip(b)
                            .all(|(x, y)| x.to_bits() == y.to_bits())
                };
                if !same(&resp.out, &want_rows) {
                    return Err(format!(
                        "{kernel}: slot {slot} (len {len}) diverged from \
                         the sequential masked run"));
                }
                // reference 2: the fully-unpadded computation of this
                // request — no padded tensor anywhere in the reference
                let unpadded = unpadded_reference(
                    resolved.as_ref(), *shape, *seed, slot, rq, rk, rv,
                    *len);
                if !same(&resp.out, &unpadded) {
                    return Err(format!(
                        "{kernel}: slot {slot} (len {len}) diverged from \
                         the unpadded computation"));
                }
            }
            gw.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_clustered_attention_rows_are_row_stochastic() {
    forall(
        "clustered attention rows sum to 1",
        0xC1D5,
        12,
        |rng| {
            let n = 24 + rng.below(25); // 24..=48
            let q = Matrix::randn(n, 8, rng);
            let k = Matrix::randn(n, 8, rng);
            let clusters = 2 + rng.below(5); // 2..=6
            let cl = cluster_queries(&q, clusters, 31, 5, rng);
            (q, k, cl)
        },
        |(q, k, cl): &(Matrix, Matrix, Clustering)| {
            let a_c = clustered_attention_matrix(q, k, cl);
            for r in 0..a_c.rows {
                let s: f32 = a_c.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-5 {
                    return Err(format!("A^c row {r} sums to {s}"));
                }
                if a_c.row(r).iter().any(|&w| w < 0.0) {
                    return Err(format!("A^c row {r} has negative mass"));
                }
            }
            let a_t = improved_clustered_attention_matrix(q, k, cl, 8);
            for r in 0..a_t.rows {
                let s: f32 = a_t.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-4 {
                    return Err(format!("A^t row {r} sums to {s}"));
                }
                if a_t.row(r).iter().any(|&w| w < -1e-6) {
                    return Err(format!("A^t row {r} has negative mass"));
                }
            }
            Ok(())
        },
    );
}
