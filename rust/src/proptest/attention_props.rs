//! Properties of the batched multi-head attention engine and the tiled
//! compute core:
//!
//!  1. **Determinism contract** — `run_batch` over any pool size is
//!     bit-for-bit identical to the sequential per-slice loop
//!     (`run_batch_seq`) for every registered kernel family.
//!  2. **Intra-slice determinism** — `AttentionKernel::run` with a
//!     parallel `ExecCtx` (row-partitioned GEMM, streaming softmax,
//!     clustering, top-k) is bit-for-bit identical to the sequential
//!     ctx, for every kernel family and worker count.
//!  3. **Blocked GEMM ≡ naive** — the cache-blocked, panel-packed GEMM
//!     (NN and NT) matches the naive i-k-j scalar loop bit for bit on
//!     random shapes, including non-multiples of the tile sizes, for
//!     any worker count.
//!  4. **Row-stochasticity** — clustered attention matrices (plain and
//!     improved) stay probability distributions row-wise.
//!  5. **Gateway determinism** — a live `ServingGateway` co-batch
//!     (threaded ingress, deadline batcher, shared pool, intra-slice
//!     parallelism on) returns the same bits as the sequential
//!     per-slice loop over the same padded batch.

use std::time::Duration;

use crate::attention::{clustered_attention_matrix,
                       improved_clustered_attention_matrix, kernel_by_name,
                       kernel_for, run_batch_seq, Variant};
use crate::clustering::{cluster_queries, Clustering};
use crate::coordinator::{pad_batch, valid_rows, Bucket, GatewayOptions,
                         GatewayShape, ServingGateway};
use crate::exec::{ExecCtx, WorkerPool};
use crate::proptest::forall;
use crate::tensor::batch::BatchMatrix;
use crate::tensor::{gemm, Matrix};

/// Small-hyperparameter instances of every kernel family (LSH chunk 16
/// divides the generated Ns).
fn all_variants() -> Vec<Variant> {
    vec![
        Variant::Full,
        Variant::SharedFull,
        Variant::Clustered { clusters: 4, bits: 31, iters: 5 },
        Variant::ImprovedClustered { clusters: 4, bits: 31, iters: 5,
                                     topk: 8 },
        Variant::OracleTop { topk: 8 },
        Variant::Lsh { rounds: 2, chunk: 16 },
    ]
}

#[test]
fn prop_run_batch_is_bit_identical_to_sequential_loop() {
    forall(
        "run_batch ≡ per-slice run, all variants",
        0xBA7C11ED,
        6,
        |rng| {
            let b = 1 + rng.below(2); // 1..=2
            let h = 1 + rng.below(3); // 1..=3
            let n = 32 * (1 + rng.below(2)); // 32 | 64
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = BatchMatrix::randn(b, h, n, d, rng);
            let k = BatchMatrix::randn(b, h, n, d, rng);
            let v = BatchMatrix::randn(b, h, n, d, rng);
            let workers = 2 + rng.below(4); // 2..=5
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            // par_rows = 1 forces the intra-slice compute core parallel
            // on top of the slice-axis parallelism
            let ctx =
                ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let par = kernel.run_batch(q, k, v, *seed, &ctx);
                let seq = run_batch_seq(kernel.as_ref(), q, k, v, *seed);
                if !par.bit_identical(&seq) {
                    return Err(format!(
                        "{} diverged from sequential (B={} H={} N={} \
                         workers={workers})",
                        var.name(), q.batch, q.heads, q.rows));
                }
                if (par.batch, par.heads, par.rows, par.cols)
                    != (q.batch, q.heads, q.rows, v.cols)
                {
                    return Err(format!("{} bad output shape", var.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kernel_run_is_bit_identical_across_exec_ctx() {
    forall(
        "run(ctx parallel) ≡ run(ctx sequential), all variants",
        0x1A7A_C0DE,
        5,
        |rng| {
            let n = 32 * (1 + rng.below(3)); // 32 | 64 | 96
            let d = 8 * (1 + rng.below(2)); // 8 | 16
            let q = Matrix::randn(n, d, rng);
            let k = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, d, rng);
            let workers = 2 + rng.below(5); // 2..=6
            let seed = rng.next_u64();
            (q, k, v, workers, seed)
        },
        |(q, k, v, workers, seed)| {
            let par = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let seq = ExecCtx::sequential();
            for var in all_variants() {
                let kernel = kernel_for(&var);
                let mut r1 = crate::prng::Xoshiro256::new(*seed);
                let mut r2 = crate::prng::Xoshiro256::new(*seed);
                let a = kernel.run(q, k, v, &mut r1, &seq);
                let b = kernel.run(q, k, v, &mut r2, &par);
                if !a.bit_identical(&b) {
                    return Err(format!(
                        "{} intra-slice parallel diverged (N={} \
                         workers={workers})",
                        var.name(), q.rows));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_gemm_is_bit_identical_to_naive() {
    forall(
        "blocked GEMM ≡ naive i-k-j loop, NN and NT, ragged shapes",
        0x6E33_1B1D,
        10,
        |rng| {
            // spans sub-tile, tile-aligned and multi-panel shapes
            let m = 1 + rng.below(70);
            let k = 1 + rng.below(2 * gemm::KC + 10);
            let n = 1 + rng.below(40);
            let a = Matrix::randn(m, k, rng);
            let b_nn = Matrix::randn(k, n, rng);
            let b_nt = Matrix::randn(n, k, rng);
            let workers = 1 + rng.below(5); // 1..=5
            (a, b_nn, b_nt, workers)
        },
        |(a, b_nn, b_nt, workers)| {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(*workers), 1);
            let nn = gemm::matmul_nn(a, b_nn, &ctx);
            if !nn.bit_identical(&gemm::naive_nn(a, b_nn)) {
                return Err(format!(
                    "NN diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nn.cols));
            }
            let nt = gemm::matmul_nt(a, b_nt, &ctx);
            if !nt.bit_identical(&gemm::naive_nt(a, b_nt)) {
                return Err(format!(
                    "NT diverged at ({}, {}, {}) workers={workers}",
                    a.rows, a.cols, b_nt.rows));
            }
            Ok(())
        },
    );
}

/// One gateway request: (q, k, v) blocks plus the valid length.
type GatewayReq = (Vec<f32>, Vec<f32>, Vec<f32>, usize);

#[test]
fn prop_gateway_cobatch_is_bit_identical_to_sequential_padded_run() {
    const N: usize = 32;
    forall(
        "gateway co-batch ≡ run_batch_seq over the padded batch",
        0x6A7E3A1D,
        4,
        |rng| {
            let kernels = ["full", "clustered-4", "i-clustered-4", "lsh-1"];
            let kernel = kernels[rng.below(kernels.len())].to_string();
            let shape =
                GatewayShape { heads: 1 + rng.below(2), dk: 8, dv: 8 };
            let n_req = 2 + rng.below(2); // 2..=3
            let reqs: Vec<GatewayReq> = (0..n_req)
                .map(|_| {
                    let len = 1 + rng.below(N); // 1..=N
                    (rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.qk_len(len)),
                     rng.normal_vec(shape.v_len(len)),
                     len)
                })
                .collect();
            let workers = 2 + rng.below(3); // 2..=4
            let seed = rng.next_u64();
            (kernel, shape, reqs, workers, seed)
        },
        |(kernel, shape, reqs, workers, seed)| {
            let gw = ServingGateway::start(
                *shape,
                vec![Bucket::native(kernel.clone(), N, reqs.len())],
                GatewayOptions {
                    // the size trigger must form the batch, not the clock
                    max_wait: Duration::from_secs(10),
                    queue_capacity: reqs.len() + 1,
                    workers: *workers,
                    seed: *seed,
                    route_up: false,
                    // exercise intra-slice parallelism on the live path
                    par_rows: 1,
                },
            )
            .map_err(|e| format!("gateway start: {e}"))?;
            let rxs: Vec<_> = reqs
                .iter()
                .map(|(q, k, v, len)| {
                    gw.submit_blocking(q.clone(), k.clone(), v.clone(),
                                       *len)
                        .expect("submit")
                })
                .collect();
            let responses: Vec<_> = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(30))
                            .expect("gateway reply"))
                .collect();

            // reference: sequential loop over the identically padded batch
            let blocks = |sel: fn(&GatewayReq) -> (&[f32], usize)| {
                reqs.iter().map(sel).collect::<Vec<_>>()
            };
            let q = pad_batch(&blocks(|r| (&r.0, r.3)), shape.heads, N,
                              shape.dk);
            let k = pad_batch(&blocks(|r| (&r.1, r.3)), shape.heads, N,
                              shape.dk);
            let v = pad_batch(&blocks(|r| (&r.2, r.3)), shape.heads, N,
                              shape.dv);
            let want = run_batch_seq(
                kernel_by_name(kernel).expect("kernel").as_ref(), &q, &k,
                &v, *seed);

            for (slot, (resp, (_, _, _, len))) in
                responses.iter().zip(reqs).enumerate()
            {
                if resp.batch_occupancy != reqs.len() {
                    return Err(format!(
                        "batch composition changed: occupancy {} != {}",
                        resp.batch_occupancy, reqs.len()));
                }
                let want_rows = valid_rows(&want, slot, *len);
                let same = resp.out.len() == want_rows.len()
                    && resp.out.iter().zip(&want_rows)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!(
                        "{kernel}: slot {slot} (len {len}) diverged from \
                         the sequential padded run"));
                }
            }
            gw.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_clustered_attention_rows_are_row_stochastic() {
    forall(
        "clustered attention rows sum to 1",
        0xC1D5,
        12,
        |rng| {
            let n = 24 + rng.below(25); // 24..=48
            let q = Matrix::randn(n, 8, rng);
            let k = Matrix::randn(n, 8, rng);
            let clusters = 2 + rng.below(5); // 2..=6
            let cl = cluster_queries(&q, clusters, 31, 5, rng);
            (q, k, cl)
        },
        |(q, k, cl): &(Matrix, Matrix, Clustering)| {
            let a_c = clustered_attention_matrix(q, k, cl);
            for r in 0..a_c.rows {
                let s: f32 = a_c.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-5 {
                    return Err(format!("A^c row {r} sums to {s}"));
                }
                if a_c.row(r).iter().any(|&w| w < 0.0) {
                    return Err(format!("A^c row {r} has negative mass"));
                }
            }
            let a_t = improved_clustered_attention_matrix(q, k, cl, 8);
            for r in 0..a_t.rows {
                let s: f32 = a_t.row(r).iter().sum();
                if (s - 1.0).abs() >= 1e-4 {
                    return Err(format!("A^t row {r} sums to {s}"));
                }
                if a_t.row(r).iter().any(|&w| w < -1e-6) {
                    return Err(format!("A^t row {r} has negative mass"));
                }
            }
            Ok(())
        },
    );
}
