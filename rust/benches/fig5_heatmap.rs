//! Figure 5 (suppl. §C.2): masked-copy-task accuracy heatmaps — clusters
//! × sequence length for clustered/i-clustered, hashing rounds × length
//! for the Reformer baseline, with the full-attention reference column.
//!
//! Paper: 5000 iterations @ batch 32.  Default here: CT_STEPS_COPY=150
//! (shape emerges as a *trend*); CT_FULL=1 expands lengths/variants and
//! CT_STEPS_COPY=2000+ approaches the paper's saturated heatmap.

use clustered_transformers::benchlib::traincache::{env_usize, eval_score,
                                                   full_grid,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::runtime::Runtime;

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS_COPY", 150) as u64;

    let lengths: Vec<usize> =
        if full_grid() { vec![32, 64, 128] } else { vec![32, 64] };
    let cluster_counts: Vec<usize> =
        if full_grid() { vec![8, 15, 30] } else { vec![8, 15] };
    let lsh_rounds: Vec<usize> =
        if full_grid() { vec![1, 4, 8] } else { vec![1, 4] };

    // full-attention reference column
    let mut ref_tbl = Table::new("fig5-ref: full attention accuracy",
                                 &["N", "accuracy"]);
    for &n in &lengths {
        let acc = point(&rt, &format!("copy-n{n}-full"), steps);
        ref_tbl.row(vec![n.to_string(), acc]);
    }
    ref_tbl.emit();

    for (title, prefix, grid) in [
        ("fig5a: i-clustered accuracy (clusters × N)", "i-clustered",
         &cluster_counts),
        ("fig5b: clustered accuracy (clusters × N)", "clustered",
         &cluster_counts),
        ("fig5c: Reformer accuracy (rounds × N)", "lsh", &lsh_rounds),
    ] {
        let mut headers = vec!["param \\ N".to_string()];
        headers.extend(lengths.iter().map(|n| n.to_string()));
        let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut tbl = Table::new(title, &href);
        for &p in grid.iter() {
            let mut row = vec![p.to_string()];
            for &n in &lengths {
                row.push(point(&rt, &format!("copy-n{n}-{prefix}-{p}"),
                               steps));
            }
            tbl.row(row);
        }
        tbl.emit();
    }
    println!("expected shape (paper fig. 5): i-clustered solves the task \
              at EVERY (clusters, N) cell;\nclustered and lsh degrade as N \
              grows unless clusters/rounds grow with it.");
}

fn point(rt: &Runtime, model: &str, steps: u64) -> String {
    match train_or_load(rt, model, steps) {
        Ok(ckpt) => eval_score(rt, &format!("{model}.forward"),
                               &ckpt.params, 4)
            .map(|s| format!("{:.2}", s.value))
            .unwrap_or_else(|_| "-".into()),
        Err(e) => {
            eprintln!("  {model}: {e:#}");
            "-".into()
        }
    }
}
