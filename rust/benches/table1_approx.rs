//! Table 1: "train with X, evaluate with Y" approximation matrix on the
//! WSJ-analog task.
//!
//! One checkpoint per training variant; the same flat parameter vector is
//! then executed under every evaluation variant's forward artifact (the
//! checkpoint transfer the paper's §4.1 relies on).  Shared-QK rows
//! (shared-full, lsh-*) only evaluate against shared-QK columns, exactly
//! like the paper's table.

use clustered_transformers::benchlib::traincache::{
    env_usize, eval_score, full_grid, train_or_load,
};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::runtime::Runtime;

fn is_shared_qk(v: &str) -> bool {
    v == "shared-full" || v.starts_with("lsh")
}

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS", 60) as u64;

    let mut train_with: Vec<&str> = vec![
        "full", "shared-full", "lsh-1", "clustered-25", "i-clustered-25",
    ];
    let mut eval_with: Vec<&str> = vec![
        "full", "shared-full", "lsh-1", "clustered-25", "clustered-50",
        "i-clustered-25", "i-clustered-50", "oracle-top-16",
    ];
    if full_grid() {
        train_with.push("lsh-4");
        eval_with.push("lsh-4");
    }

    let mut headers: Vec<&str> = vec!["evaluate \\ train"];
    headers.extend(train_with.iter());
    let mut tbl = Table::new(
        "table1: validation PER% — train with column, evaluate with row \
         (WSJ-analog, 6 layers)",
        &headers,
    );

    // train (or load) each column's checkpoint once
    let mut ckpts = Vec::new();
    for tv in &train_with {
        let model = format!("wsj-l6-{tv}");
        match train_or_load(&rt, &model, steps) {
            Ok(c) => ckpts.push(Some(c)),
            Err(e) => {
                eprintln!("  {model}: {e:#}");
                ckpts.push(None);
            }
        }
    }

    for ev in &eval_with {
        let mut row = vec![ev.to_string()];
        for (ti, tv) in train_with.iter().enumerate() {
            // paper leaves shared/unshared cross-cells empty
            let compatible = is_shared_qk(ev) == is_shared_qk(tv)
                || !is_shared_qk(tv) && !is_shared_qk(ev);
            let cell = match (&ckpts[ti], compatible,
                              is_shared_qk(ev) == is_shared_qk(tv)) {
                (Some(ckpt), _, true) => {
                    let fwd = format!("wsj-l6-{ev}.forward");
                    match eval_score(&rt, &fwd, &ckpt.params, 3) {
                        Ok(s) => format!("{:.1}", s.value),
                        Err(_) => "-".into(),
                    }
                }
                _ => "-".into(),
            };
            row.push(cell);
        }
        tbl.row(row);
    }
    tbl.emit();
    println!("expected shape (paper table 1): i-clustered rows approximate \
              full far better than clustered or lsh rows;\noracle-top \
              (exact top-k only) underperforms i-clustered because the \
              attention tail matters.");
}
