//! Figure 7 (suppl.): training-loss convergence vs wall-clock time for
//! the WSJ-analog variants.  Curves come from the cached checkpoints'
//! recorded loss curves (train the models via fig1/table benches or
//! directly here).

use clustered_transformers::benchlib::traincache::{env_usize,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::jsonio::Value;
use clustered_transformers::runtime::Runtime;

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS", 60) as u64;

    let variants = ["full", "lsh-1", "clustered-25", "i-clustered-25"];
    let mut curves: Vec<(String, f64, Vec<(f64, f64)>)> = Vec::new();
    for v in variants {
        let model = format!("wsj-l6-{v}");
        match train_or_load(&rt, &model, steps) {
            Ok(ckpt) => {
                let sps = ckpt.meta.get("seconds_per_step").as_f64()
                    .unwrap_or(0.0);
                curves.push((v.to_string(), sps,
                             curve_points(&ckpt.meta)));
            }
            Err(e) => eprintln!("  {model}: {e:#}"),
        }
    }

    // render: loss at matched wall-clock checkpoints
    let max_wall = curves
        .iter()
        .map(|(_, sps, c)| sps * c.last().map(|p| p.0).unwrap_or(0.0))
        .fold(0.0, f64::max);
    let mut headers = vec!["wall s".to_string()];
    headers.extend(curves.iter().map(|(v, _, _)| v.clone()));
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut tbl = Table::new(
        "fig7: train loss vs wall-clock (WSJ-analog, 6 layers)", &href);
    for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let t = max_wall * frac;
        let mut row = vec![format!("{t:.0}")];
        for (_, sps, curve) in &curves {
            let step = if *sps > 0.0 { t / sps } else { 0.0 };
            let loss = curve
                .iter()
                .take_while(|(s, _)| *s <= step)
                .last()
                .map(|(_, l)| *l);
            row.push(loss.map(|l| format!("{l:.3}"))
                     .unwrap_or_else(|| "·".into()));
        }
        tbl.row(row);
    }
    tbl.emit();
    println!("expected shape (paper fig. 7): clustered variants reach low \
              loss sooner in wall-clock;\nlsh trails both; full catches up \
              only late.");
}

fn curve_points(meta: &Value) -> Vec<(f64, f64)> {
    meta.get("curve")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|p| {
            let pair = p.as_arr()?;
            Some((pair[0].as_f64()?, pair[1].as_f64()?))
        })
        .collect()
}
