//! Table 3: Switchboard-analog convergence — WER-analog (PER on the
//! harder corpus), time per epoch, wall-clock to best validation.  The
//! longer sequences (N = 384) widen the clustered-vs-full gap, which is
//! the paper's point.

use clustered_transformers::benchlib::traincache::{env_usize, eval_score,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::runtime::Runtime;

const STEPS_PER_EPOCH: f64 = 50.0;

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS", 60) as u64;

    let mut tbl = Table::new(
        "table3: SWB-analog convergence (6 layers, N=384)",
        &["variant", "test WER-analog %", "s/epoch (50 steps)",
          "total wall s"],
    );
    for v in ["full", "clustered-25", "i-clustered-25"] {
        let model = format!("swb-l6-{v}");
        match train_or_load(&rt, &model, steps) {
            Ok(ckpt) => {
                let sps = ckpt.meta.get("seconds_per_step").as_f64()
                    .unwrap_or(0.0);
                let wall = ckpt.meta.get("wall_seconds").as_f64()
                    .unwrap_or(0.0);
                let wer = eval_score(&rt, &format!("{model}.forward"),
                                     &ckpt.params, 3)
                    .map(|s| format!("{:.1}", s.value))
                    .unwrap_or_else(|_| "-".into());
                tbl.row(vec![v.to_string(), wer,
                             format!("{:.1}", sps * STEPS_PER_EPOCH),
                             format!("{wall:.1}")]);
            }
            Err(e) => eprintln!("  {model}: {e:#}"),
        }
    }
    tbl.emit();
    println!("expected shape (paper table 3): clustered ≈ 2× faster/epoch, \
              i-clustered ≈ 1.5×, with i-clustered matching full's \
              error at lower total wall-clock.");
}
