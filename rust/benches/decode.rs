//! Decode bench: incremental KV-cached decode vs full-prefix recompute,
//! tokens/sec per kernel family.
//!
//! One session per (kernel, N): prefill N/2 rows, then decode steps of
//! `step_len` new rows until the history reaches N, submitted through a
//! `CachingBackend` twice — once with an unbounded `KvCache` (every
//! step after the prefill hits and solves only the span) and once with
//! a zero-capacity cache (every step misses and recomputes the full
//! history through the wrapped backend, the no-cache serving baseline).
//! Both runs draw the same session PRNG streams, so their span outputs
//! must be bit-identical — the bench asserts it, making this a live
//! check of the decode contract on top of a perf comparison.
//!
//! Expected shape: the full family's recompute cost grows as O(N²) per
//! step while the cached path pays O(m·N), so cached tokens/sec wins by
//! ~N/m at the tail; clustered re-clusters the history each step (the
//! exact default) so its win is the pruned centroid pass; lsh gains
//! nothing by construction (joint bucketing defeats incremental reuse)
//! and documents the honest ~1× floor.  `CT_SMOKE=1` shrinks the grid
//! for CI.
//!
//! The second section is the **decode curve**: cached tokens/sec as a
//! function of history length per family, plus the per-step session
//! state each family pins.  The linear family runs *causal* and rides
//! the recurrent-state cache path — a step updates a constant-size
//! `(S, z)` accumulator and costs O(m·D²) no matter the history — so
//! its curve stays flat while every KV-panel family decays with the
//! history it must rescan (full: O(m·N) per step) or re-cluster.
//!
//! The third section is the **quantized column** (`decode-quant/*`
//! records): under one fixed LRU row budget, the i8 panel store keeps
//! ≥4× as many live sessions as the exact f32 store (charged
//! `⌈len/4⌉` vs `len` rows — asserted, and reported as
//! `sessions_per_gb` / `density_x`), and the quantized decode's
//! tokens/sec and `max_abs_error` against the exact f32 run are
//! recorded with a `quant_within_tol` flag the bench asserts: smooth
//! families within a small fixed band, the discrete families within
//! the convex-hull envelope of the value rows.

use std::sync::Arc;
use std::time::Instant;

use clustered_transformers::attention::{AttnBatch, CacheQuant, CacheRef,
                                        CachingBackend, KvCache,
                                        KvCacheOptions, SessionRef};
use clustered_transformers::benchlib::{self, BenchRecord, Stats, Table};
use clustered_transformers::config::init_logging;
use clustered_transformers::exec::ExecCtx;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::tensor::batch::BatchMatrix;

const HEADS: usize = 2;
const D: usize = 32;

fn smoke() -> bool {
    std::env::var("CT_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// (1, H, len, D) prefix of a (1, H, total, D) history — bit-identical
/// prefixes are what the cache-hit path appends and verifies against.
fn prefix(t: &BatchMatrix, len: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(1, t.heads, len, t.cols);
    for h in 0..t.heads {
        out.slice_mut(h)
            .copy_from_slice(&t.view(h).data[..len * t.cols]);
    }
    out
}

struct DecodeRun {
    /// Decoded tokens (rows after the prefill).
    tokens: usize,
    /// Wall seconds over the decode steps (prefill excluded).
    wall_s: f64,
    /// Per-step seconds (decode steps only).
    step_samples: Vec<f64>,
    hit_rate: f64,
    /// Concatenated span rows of every decode step, for bit-compare.
    outs: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn run_decode(kernel: &str, cache_rows: usize, quant: CacheQuant,
              q: &BatchMatrix, k: &BatchMatrix, v: &BatchMatrix,
              prefill: usize, step_len: usize, seed: u64, causal: bool)
              -> DecodeRun {
    let total = q.rows;
    let cache = Arc::new(KvCache::new(KvCacheOptions {
        capacity_rows: cache_rows,
        growth: 1.0,
        quant,
    }));
    let backend = CachingBackend::native(kernel, cache.clone())
        .expect("kernel not in the registry");
    let ctx = ExecCtx::sequential();
    let sid = 1u64;
    let mut run = DecodeRun {
        tokens: 0,
        wall_s: 0.0,
        step_samples: Vec::new(),
        hit_rate: 0.0,
        outs: Vec::new(),
    };
    let mut span = 0usize;
    let mut len = prefill;
    loop {
        let (qp, kp, vp) = (prefix(q, len), prefix(k, len), prefix(v, len));
        let lens = [len];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: sid, generation: 0 },
            span_start: span,
        })];
        let batch = AttnBatch::new(&qp, &kp, &vp, seed)
            .with_lens(&lens)
            .with_sessions(&sessions)
            .with_causal(causal);
        let t0 = Instant::now();
        let out = backend.execute(&batch, &ctx);
        let dt = t0.elapsed().as_secs_f64();
        if span > 0 {
            // decode step: time it and keep its span rows
            run.tokens += len - span;
            run.wall_s += dt;
            run.step_samples.push(dt);
            for h in 0..HEADS {
                let data = out.view(h).data;
                run.outs
                    .extend_from_slice(&data[span * D..len * D]);
            }
        }
        if len == total {
            break;
        }
        span = len;
        len = (len + step_len).min(total);
    }
    run.hit_rate = cache.counters().hit_rate();
    run
}

/// Bytes of session state the cache pins per decode step for a family
/// holding a history of `len` rows: the KV-panel families keep the
/// full q/k/v panels, `heads * len * (2*dk + dv) * 4`, while the
/// linear family keeps one `(S: D×D, z: D)` accumulator per head —
/// `heads * (dk*dv + dk) * 4`, independent of the history.  Mirrors
/// `RecurrentState::state_bytes` and the panel charge in the cache.
fn state_bytes(kernel: &str, len: usize) -> usize {
    if kernel == "linear" {
        HEADS * (D * D + D) * 4
    } else {
        HEADS * len * (2 * D + D) * 4
    }
}

/// Decode curve: cached tokens/sec vs history length.  Prefill `h`
/// rows, then time `steps` decode steps of `step_len` rows against an
/// unbounded cache.  The linear family runs causal (the recurrent
/// O(m·D²) path); the panel families rescan their history each step.
/// At the smallest history the run is repeated with a zero-capacity
/// cache and the span outputs are asserted bit-identical — the same
/// live contract check the comparison section does, kept off the long
/// histories where the full recompute would dominate the bench.
fn decode_curve(seed: u64, records: &mut Vec<BenchRecord>) {
    let (histories, steps, step_len): (Vec<usize>, usize, usize) =
        if smoke() {
            (vec![256, 1024], 4, 4)
        } else if benchlib::traincache::full_grid() {
            (vec![256, 1024, 4096, 16384], 8, 4)
        } else {
            (vec![256, 1024, 4096], 8, 4)
        };
    let families = ["full", "oracle-top-32", "clustered-16", "linear"];
    let mut table = Table::new(
        &format!(
            "decode curve: tokens/sec vs history length, {steps} steps \
             of {step_len} rows, H={HEADS} D={D} — linear runs causal \
             on the O(1) recurrent-state path"),
        &["kernel", "history", "tok/s", "hit %", "state B/step",
          "p50 ms/step", "≡ recompute"],
    );
    for kernel in families {
        let causal = kernel == "linear";
        for (i, &h) in histories.iter().enumerate() {
            let total = h + steps * step_len;
            let mut rng = Xoshiro256::new(seed ^ ((h as u64) << 1));
            let q = BatchMatrix::randn(1, HEADS, total, D, &mut rng);
            let k = BatchMatrix::randn(1, HEADS, total, D, &mut rng);
            let v = BatchMatrix::randn(1, HEADS, total, D, &mut rng);
            let cached = run_decode(kernel, usize::MAX, CacheQuant::Off,
                                    &q, &k, &v, h, step_len, seed,
                                    causal);
            let checked = if i == 0 {
                let redone = run_decode(kernel, 0, CacheQuant::Off, &q,
                                        &k, &v, h, step_len, seed,
                                        causal);
                let identical = cached.outs.len() == redone.outs.len()
                    && cached
                        .outs
                        .iter()
                        .zip(&redone.outs)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical,
                        "{kernel}/hist={h}: cached decode diverged \
                         from the full recompute");
                "true"
            } else {
                "-"
            };
            let tok_s = cached.tokens as f64 / cached.wall_s.max(1e-9);
            let bytes = state_bytes(kernel, total);
            let st = Stats::from_samples(&cached.step_samples);
            table.row(vec![
                kernel.to_string(),
                h.to_string(),
                format!("{tok_s:.0}"),
                format!("{:.0}", 100.0 * cached.hit_rate),
                bytes.to_string(),
                format!("{:.3}", st.p50_s * 1e3),
                checked.to_string(),
            ]);
            records.push(
                BenchRecord::from_stats(
                    &format!("decode-curve/{kernel}/hist={h}"),
                    step_len, &st)
                    .with("tokens_per_sec_cached", tok_s)
                    .with("history_rows", h as f64)
                    .with("state_bytes_per_step", bytes as f64)
                    .with("cache_hit_rate", cached.hit_rate),
            );
        }
    }
    table.emit();
    println!("\nexpected: linear tokens/sec stays flat (±10%) from the \
              shortest to the longest history — its recurrent state is \
              {} bytes regardless of length — while the panel families \
              decay as O(m·N) rescans (full) or re-clustering charges \
              grow with the history.",
             state_bytes("linear", 0));
}

/// Quantized column: session density under one fixed LRU row budget,
/// plus the tokens/sec and numeric-error cost of decoding from i8
/// panels.
///
/// Density protocol: a budget of `4·L` charged rows, `L`-row sessions.
/// The exact store charges `L` per session (4 survive the LRU); the i8
/// store charges `⌈L/4⌉` (16 survive) — live sessions counted straight
/// off `used_rows()`, so the assert exercises the real eviction
/// accounting, not an arithmetic identity.
fn decode_quant(seed: u64, records: &mut Vec<BenchRecord>) {
    // --- session density under one fixed budget ---
    let l = 64usize;
    let budget = 4 * l;
    let sessions = 32u64;
    let ctx = ExecCtx::sequential();
    let mut live = [0usize; 2];
    for (slot, quant) in
        [(0, CacheQuant::Off), (1, CacheQuant::I8PerPanel)]
    {
        let cache = Arc::new(KvCache::new(KvCacheOptions {
            capacity_rows: budget,
            growth: 1.0,
            quant,
        }));
        let backend = CachingBackend::native("full", cache.clone())
            .expect("kernel not in the registry");
        let mut rng = Xoshiro256::new(seed ^ 0xD417);
        let q = BatchMatrix::randn(1, HEADS, l, D, &mut rng);
        let k = BatchMatrix::randn(1, HEADS, l, D, &mut rng);
        let v = BatchMatrix::randn(1, HEADS, l, D, &mut rng);
        for sid in 0..sessions {
            let lens = [l];
            let srefs = [Some(SessionRef {
                cache: CacheRef { session: sid, generation: 0 },
                span_start: 0,
            })];
            let batch = AttnBatch::new(&q, &k, &v, seed)
                .with_lens(&lens)
                .with_sessions(&srefs);
            let _ = backend.execute(&batch, &ctx);
        }
        let charge = match quant {
            CacheQuant::Off => l,
            _ => l.div_ceil(4),
        };
        live[slot] = cache.used_rows() / charge;
    }
    let density_x = live[1] as f64 / live[0].max(1) as f64;
    assert!(density_x >= 4.0,
            "quantized store kept {}x the exact store's sessions \
             ({} vs {}) — expected >= 4x", density_x, live[1], live[0]);
    // the budget in true panel bytes (q, k, v rows across heads)
    let row_bytes = HEADS * 3 * D * 4;
    let budget_gb = (budget * row_bytes) as f64 / 1e9;
    let sessions_per_gb = live[1] as f64 / budget_gb;
    println!("\ndecode-quant density: budget {budget} rows — {} exact \
              vs {} quantized live sessions ({density_x:.1}x, \
              {sessions_per_gb:.0} sessions/GB quantized)",
             live[0], live[1]);

    // --- tokens/sec + error vs the exact f32 decode ---
    let n: usize = if smoke() { 256 } else { 512 };
    let prefill = n / 2;
    let step_len = 16;
    // discrete families can flip an assignment/bucket under the
    // ≤ scale/2 perturbation: their sound band is the convex-hull
    // envelope 2·max|V|; the smooth full family gets a small fixed one
    let families = [("full", false), ("clustered-16", true),
                    ("lsh-2", true)];
    let mut table = Table::new(
        &format!(
            "decode-quant[N={n}]: prefill {prefill}, steps of \
             {step_len} rows, H={HEADS} D={D} — i8 panels vs the exact \
             f32 decode"),
        &["kernel", "mode", "tok/s", "max|err|", "within tol",
          "sessions/GB", "density x"],
    );
    for (kernel, discrete) in families {
        let mut rng = Xoshiro256::new(seed ^ 0xD418 ^ n as u64);
        let q = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
        let k = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
        let v = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
        let vmax = f64::from(
            (0..v.slices())
                .flat_map(|s| v.view(s).data.iter())
                .fold(0.0f32, |a, &x| f32::max(a, x.abs())));
        let exact = run_decode(kernel, usize::MAX, CacheQuant::Off, &q,
                               &k, &v, prefill, step_len, seed, false);
        for quant in [CacheQuant::I8PerHead, CacheQuant::I8PerPanel] {
            let qrun = run_decode(kernel, usize::MAX, quant, &q, &k, &v,
                                  prefill, step_len, seed, false);
            assert_eq!(qrun.outs.len(), exact.outs.len(),
                       "{kernel}/{}: quantized run shape drifted",
                       quant.name());
            let mut max_err = 0f64;
            let mut within = true;
            for (a, b) in qrun.outs.iter().zip(&exact.outs) {
                let err = (f64::from(*a) - f64::from(*b)).abs();
                max_err = max_err.max(err);
                let tol = if discrete {
                    2.0 * vmax + 0.05
                } else {
                    0.25 + 0.25 * f64::from(*b).abs()
                };
                within &= err <= tol;
            }
            assert!(within,
                    "{kernel}/{}: quantized decode left the declared \
                     tolerance (max |err| {max_err})", quant.name());
            let tok_s = qrun.tokens as f64 / qrun.wall_s.max(1e-9);
            let st = Stats::from_samples(&qrun.step_samples);
            table.row(vec![
                kernel.to_string(),
                quant.name().to_string(),
                format!("{tok_s:.0}"),
                format!("{max_err:.4}"),
                within.to_string(),
                format!("{sessions_per_gb:.0}"),
                format!("{density_x:.1}"),
            ]);
            records.push(
                BenchRecord::from_stats(
                    &format!("decode-quant/{kernel}/{}/N={n}",
                             quant.name()),
                    step_len, &st)
                    .with("tokens_per_sec_cached", tok_s)
                    .with("max_abs_error", max_err)
                    .with("quant_within_tol",
                          if within { 1.0 } else { 0.0 })
                    .with("sessions_per_gb", sessions_per_gb)
                    .with("density_x", density_x),
            );
        }
    }
    table.emit();
    println!("\nexpected: density 4.0x exactly (charges are \
              deterministic: ceil(L/4) vs L under one budget); \
              max|err| stays within the declared band — small for the \
              smooth full family, hull-bounded for the discrete \
              families — and tok/s tracks the exact cached run (the \
              dequantize pass is O(len·D) against an O(m·N) solve).");
}

fn main() {
    init_logging(false);
    let (sizes, step_len): (Vec<usize>, usize) = if smoke() {
        (vec![512], 16)
    } else if benchlib::traincache::full_grid() {
        (vec![512, 1024, 2048], 4)
    } else {
        (vec![512, 1024], 4)
    };
    let families = ["full", "shared-full", "oracle-top-32",
                    "clustered-16", "i-clustered-16", "lsh-2",
                    "linear"];
    let seed = 0u64;
    let mut records = Vec::new();

    for &n in &sizes {
        let prefill = n / 2;
        let mut table = Table::new(
            &format!(
                "decode[N={n}]: prefill {prefill}, steps of {step_len} \
                 rows, H={HEADS} D={D} — cached incremental vs full \
                 recompute"),
            &["kernel", "tok/s cached", "tok/s recompute", "speedup",
              "hit %", "p50 ms/step", "≡ recompute"],
        );
        for kernel in families {
            let mut rng = Xoshiro256::new(seed ^ n as u64);
            let q = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
            let k = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
            let v = BatchMatrix::randn(1, HEADS, n, D, &mut rng);
            let cached = run_decode(kernel, usize::MAX, CacheQuant::Off,
                                    &q, &k, &v, prefill, step_len, seed,
                                    false);
            let redone = run_decode(kernel, 0, CacheQuant::Off, &q, &k,
                                    &v, prefill, step_len, seed, false);
            // the decode contract, live: cached spans == recompute
            // spans, bit for bit
            let identical = cached.outs.len() == redone.outs.len()
                && cached
                    .outs
                    .iter()
                    .zip(&redone.outs)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(identical,
                    "{kernel}/N={n}: cached decode diverged from the \
                     full recompute");
            let tok_s = cached.tokens as f64 / cached.wall_s.max(1e-9);
            let tok_s_re = redone.tokens as f64 / redone.wall_s.max(1e-9);
            let st = Stats::from_samples(&cached.step_samples);
            table.row(vec![
                kernel.to_string(),
                format!("{tok_s:.0}"),
                format!("{tok_s_re:.0}"),
                format!("{:.2}x", tok_s / tok_s_re.max(1e-9)),
                format!("{:.0}", 100.0 * cached.hit_rate),
                format!("{:.3}", st.p50_s * 1e3),
                identical.to_string(),
            ]);
            records.push(
                BenchRecord::from_stats(&format!("{kernel}/N={n}"),
                                        step_len, &st)
                    .with("tokens_per_sec_cached", tok_s)
                    .with("tokens_per_sec_recompute", tok_s_re)
                    .with("speedup", tok_s / tok_s_re.max(1e-9))
                    .with("cache_hit_rate", cached.hit_rate),
            );
        }
        table.emit();
    }
    println!("\nexpected: full-family cached decode beats recompute by \
              ~N/step_len at N >= 512 (O(m·N) vs O(N²) per step); \
              shared-full and oracle-top track it; clustered wins on \
              the pruned centroid pass; lsh sits near 1x (joint \
              bucketing defeats incremental reuse — documented floor).");
    decode_curve(seed, &mut records);
    decode_quant(seed, &mut records);
    let _ = benchlib::write_bench_json("decode", &records);
}
