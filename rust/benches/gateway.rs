//! Gateway bench: replay a mixed-length synthetic trace through the
//! multi-bucket native serving gateway and report per-bucket serving
//! metrics — p50/p99 latency, rows/sec, batch occupancy, padding-waste
//! ratio — plus the determinism check (a live gateway co-batch is
//! bit-identical to the sequential per-slice loop over the same padded
//! batch).
//!
//! This is the serving-side companion of fig. 4: where fig. 4 sweeps raw
//! kernel throughput, this sweeps the *traffic shape* — log₂-uniform
//! request lengths against power-of-two buckets, the regime where
//! clustered attention's linear complexity pays at the tail buckets.
//! `CT_FULL=1` enlarges the trace.

use std::time::{Duration, Instant};

use clustered_transformers::attention::{kernel_by_name, run_batch_seq};
use clustered_transformers::benchlib::{self, BenchRecord, Table};
use clustered_transformers::config::init_logging;
use clustered_transformers::coordinator::{
    bucket_report, pad_batch, replay_blocking, synthetic_trace,
    valid_rows, Bucket, GatewayOptions, GatewayShape, ServingGateway,
    BUCKET_REPORT_HEADERS,
};
use clustered_transformers::prng::Xoshiro256;

const SHAPE: GatewayShape = GatewayShape { heads: 4, dk: 32, dv: 32 };
const BUCKETS: [(usize, usize); 3] = [(64, 8), (128, 8), (256, 4)];

fn gateway(kernel: &str, seed: u64) -> ServingGateway {
    ServingGateway::start(
        SHAPE,
        BUCKETS
            .iter()
            .map(|&(n, b)| Bucket::native(kernel, n, b))
            .collect(),
        GatewayOptions {
            max_wait: Duration::from_millis(2),
            queue_capacity: 64,
            seed,
            ..GatewayOptions::default()
        },
    )
    .expect("gateway start")
}

/// Live-path determinism: one full co-batch of staggered lengths through
/// a single-bucket gateway must be bit-identical to `run_batch_seq` over
/// the identically padded batch.
fn cobatch_bit_identical(kernel: &str, n: usize, b: usize, seed: u64)
                         -> bool {
    let mut rng = Xoshiro256::new(seed);
    let reqs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = (0..b)
        .map(|i| {
            let len = ((i + 1) * n / b).max(1); // staggered 1..=n
            (rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.v_len(len)),
             len)
        })
        .collect();
    let gw = ServingGateway::start(
        SHAPE,
        vec![Bucket::native(kernel, n, b)],
        GatewayOptions {
            max_wait: Duration::from_secs(10), // size trigger forms batch
            queue_capacity: b + 1,
            seed,
            ..GatewayOptions::default()
        },
    )
    .expect("gateway start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(q, k, v, len)| {
            gw.submit_blocking(q.clone(), k.clone(), v.clone(), *len)
                .expect("submit")
        })
        .collect();
    let responses: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).expect("reply"))
        .collect();

    let blocks = |f: fn(&(Vec<f32>, Vec<f32>, Vec<f32>, usize))
                        -> (&[f32], usize)| {
        reqs.iter().map(f).collect::<Vec<_>>()
    };
    let q = pad_batch(&blocks(|r| (&r.0, r.3)), SHAPE.heads, n, SHAPE.dk);
    let k = pad_batch(&blocks(|r| (&r.1, r.3)), SHAPE.heads, n, SHAPE.dk);
    let v = pad_batch(&blocks(|r| (&r.2, r.3)), SHAPE.heads, n, SHAPE.dv);
    let want = run_batch_seq(kernel_by_name(kernel).unwrap().as_ref(), &q,
                             &k, &v, seed);
    let ok = responses.iter().enumerate().all(|(slot, resp)| {
        if resp.batch_occupancy != b {
            return false;
        }
        let want_rows = valid_rows(&want, slot, reqs[slot].3);
        resp.out.len() == want_rows.len()
            && resp.out.iter().zip(&want_rows)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    });
    gw.shutdown();
    ok
}

fn main() {
    init_logging(false);
    let count = if benchlib::traincache::full_grid() { 512 } else { 96 };
    let clients = 8;
    let seed = 0u64;
    let max_n = BUCKETS.iter().map(|&(n, _)| n).max().unwrap();
    let mut records = Vec::new();

    for kernel in ["full", "i-clustered-32"] {
        let gw = gateway(kernel, seed);
        let trace = synthetic_trace(SHAPE, 8, max_n, count, seed);
        let t0 = Instant::now();
        let responses = replay_blocking(&gw, trace, clients);
        let wall = t0.elapsed().as_secs_f64();

        let mut headers: Vec<&str> = BUCKET_REPORT_HEADERS.to_vec();
        headers.push("bit-identical");
        let mut table = Table::new(
            &format!(
                "gateway[{kernel}]: {count} mixed-length requests \
                 (lens 8..{max_n}, log2-uniform), {clients} clients, \
                 {:.2}s wall, H={} Dk={}",
                wall, SHAPE.heads, SHAPE.dk),
            &headers,
        );
        for (row, &(n, b)) in
            bucket_report(&gw, wall).into_iter().zip(BUCKETS.iter())
        {
            let mut row = row;
            row.push(cobatch_bit_identical(kernel, n, b, seed + n as u64)
                .to_string());
            table.row(row);
        }
        table.emit();
        let total_rows: usize = responses.iter().map(|r| r.len).sum();
        println!("  total: {} requests, {:.0} valid rows/s end-to-end",
                 responses.len(),
                 total_rows as f64 / wall.max(1e-9));
        // machine-readable trajectory: one record per (kernel, bucket)
        for (&(n, _), m) in
            BUCKETS.iter().zip(gw.bucket_metrics())
        {
            use std::sync::atomic::Ordering;
            let rows = m.valid_rows.load(Ordering::Relaxed);
            records.push(BenchRecord {
                name: format!("{kernel}/N={n}"),
                rows_per_sec: rows as f64 / wall.max(1e-9),
                mean_us: m.mean_us(),
                p50_us: m.percentile_us(50.0),
                p99_us: m.percentile_us(99.0),
                iters: m.completed.load(Ordering::Relaxed) as usize,
                extra: vec![
                    ("occupancy".into(), m.occupancy()),
                    ("padding_waste".into(), m.padding_waste()),
                ],
            });
        }
        gw.shutdown();
    }
    let _ = benchlib::write_bench_json("gateway", &records);
    println!("\nexpected: tail buckets (N=256) dominate latency; \
              i-clustered keeps p99 flat where full grows with N²; \
              waste tracks the log2-uniform mix (~30-40%); bit-identical \
              must read true everywhere (determinism contract).");
}
