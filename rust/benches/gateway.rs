//! Gateway bench: replay a mixed-length (ragged) synthetic trace through
//! the multi-bucket native serving gateway and report per-bucket serving
//! metrics — p50/p99 latency, rows/sec, batch occupancy, memory-padding
//! and masked-compute waste — plus the masking contract check (a live
//! gateway co-batch response is bit-identical to the *unpadded*
//! computation of each request).
//!
//! Each kernel's trace is replayed twice: once with valid-length masking
//! on (the default — padded rows never computed) and once with it off
//! (static-shape semantics), so the table and `BENCH_gateway.json` carry
//! a masked-vs-unmasked rows/sec comparison per bucket.
//!
//! This is the serving-side companion of fig. 4: where fig. 4 sweeps raw
//! kernel throughput, this sweeps the *traffic shape* — log₂-uniform
//! request lengths against power-of-two buckets, the regime where
//! clustered attention's linear complexity pays at the tail buckets.
//! `CT_FULL=1` enlarges the trace; `CT_SMOKE=1` shrinks it for CI.

use std::time::{Duration, Instant};

use clustered_transformers::benchlib::{self, BenchRecord, Table};
use clustered_transformers::config::init_logging;
use clustered_transformers::coordinator::{
    bucket_report, replay_blocking, synthetic_trace, unpadded_reference,
    Bucket, GatewayOptions, GatewayShape, ServingGateway,
    BUCKET_REPORT_HEADERS,
};
use clustered_transformers::prng::Xoshiro256;

const SHAPE: GatewayShape = GatewayShape { heads: 4, dk: 32, dv: 32 };
const BUCKETS: [(usize, usize); 3] = [(64, 8), (128, 8), (256, 4)];

fn smoke() -> bool {
    std::env::var("CT_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn gateway(kernel: &str, seed: u64, mask: bool) -> ServingGateway {
    ServingGateway::start(
        SHAPE,
        BUCKETS
            .iter()
            .map(|&(n, b)| Bucket::native(kernel, n, b))
            .collect(),
        GatewayOptions {
            max_wait: Duration::from_millis(2),
            queue_capacity: 64,
            seed,
            mask,
            ..GatewayOptions::default()
        },
    )
    .expect("gateway start")
}

/// Live-path masking contract: one full co-batch of staggered ragged
/// lengths through a single-bucket gateway must be bit-identical to the
/// *unpadded* computation of every request (per-slice seed schedule, no
/// padded tensor anywhere in the reference).
fn cobatch_matches_unpadded(kernel: &str, n: usize, b: usize, seed: u64)
                            -> bool {
    let mut rng = Xoshiro256::new(seed);
    let reqs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = (0..b)
        .map(|i| {
            let len = ((i + 1) * n / b).max(1); // staggered 1..=n
            (rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.v_len(len)),
             len)
        })
        .collect();
    let gw = ServingGateway::start(
        SHAPE,
        vec![Bucket::native(kernel, n, b)],
        GatewayOptions {
            max_wait: Duration::from_secs(10), // size trigger forms batch
            queue_capacity: b + 1,
            seed,
            ..GatewayOptions::default()
        },
    )
    .expect("gateway start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(q, k, v, len)| {
            gw.submit_blocking(q.clone(), k.clone(), v.clone(), *len)
                .expect("submit")
        })
        .collect();
    let responses: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).expect("reply"))
        .collect();

    let resolved =
        clustered_transformers::attention::kernel_by_name(kernel).unwrap();
    let ok = responses.iter().enumerate().all(|(slot, resp)| {
        if resp.batch_occupancy != b || !resp.masked {
            return false;
        }
        let (q, k, v, len) = &reqs[slot];
        let want = unpadded_reference(resolved.as_ref(), SHAPE, seed, slot,
                                      q, k, v, *len);
        resp.out.len() == want.len()
            && resp.out.iter().zip(&want)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    });
    gw.shutdown();
    ok
}

/// Replay `trace` through a fresh gateway; returns the gateway (for its
/// per-bucket metrics), the wall seconds, and the total valid rows.
fn run_replay(kernel: &str, seed: u64, mask: bool,
              trace: Vec<clustered_transformers::coordinator::TraceItem>,
              clients: usize) -> (ServingGateway, f64, usize) {
    let gw = gateway(kernel, seed, mask);
    let t0 = Instant::now();
    let responses = replay_blocking(&gw, trace, clients);
    let wall = t0.elapsed().as_secs_f64();
    let total_rows: usize = responses.iter().map(|r| r.len).sum();
    (gw, wall, total_rows)
}

fn main() {
    init_logging(false);
    let count = if smoke() {
        24
    } else if benchlib::traincache::full_grid() {
        512
    } else {
        96
    };
    let clients = 8;
    let seed = 0u64;
    let max_n = BUCKETS.iter().map(|&(n, _)| n).max().unwrap();
    let mut records = Vec::new();

    for kernel in ["full", "i-clustered-32"] {
        let trace = synthetic_trace(SHAPE, 8, max_n, count, seed);
        // masked replay (the serving default) and the static-shape
        // comparison replay over the identical trace
        let (gw, wall, total_rows) =
            run_replay(kernel, seed, true, trace.clone(), clients);
        let (gw_un, wall_un, _) =
            run_replay(kernel, seed, false, trace, clients);

        let mut headers: Vec<&str> = BUCKET_REPORT_HEADERS.to_vec();
        headers.push("rows/s unmasked");
        headers.push("≡ unpadded");
        let mut table = Table::new(
            &format!(
                "gateway[{kernel}]: {count} ragged requests \
                 (lens 8..{max_n}, log2-uniform), {clients} clients, \
                 {:.2}s wall masked / {:.2}s unmasked, H={} Dk={}",
                wall, wall_un, SHAPE.heads, SHAPE.dk),
            &headers,
        );
        let unmasked_rows_per_sec: Vec<f64> = gw_un
            .bucket_metrics()
            .iter()
            .map(|m| {
                use std::sync::atomic::Ordering;
                m.valid_rows.load(Ordering::Relaxed) as f64
                    / wall_un.max(1e-9)
            })
            .collect();
        for ((row, &(n, b)), unmasked_rps) in bucket_report(&gw, wall)
            .into_iter()
            .zip(BUCKETS.iter())
            .zip(&unmasked_rows_per_sec)
        {
            let mut row = row;
            row.push(format!("{unmasked_rps:.0}"));
            row.push(cobatch_matches_unpadded(kernel, n, b,
                                              seed + n as u64)
                .to_string());
            table.row(row);
        }
        table.emit();
        println!("  total: {count} requests, {:.0} valid rows/s \
                  end-to-end (masked)",
                 total_rows as f64 / wall.max(1e-9));
        // machine-readable trajectory: one record per (kernel, bucket),
        // masked rows/sec as the headline with the unmasked comparison
        // column riding along
        for ((&(n, _), m), unmasked_rps) in BUCKETS
            .iter()
            .zip(gw.bucket_metrics())
            .zip(&unmasked_rows_per_sec)
        {
            use std::sync::atomic::Ordering;
            let rows = m.valid_rows.load(Ordering::Relaxed);
            records.push(BenchRecord {
                name: format!("{kernel}/N={n}"),
                rows_per_sec: rows as f64 / wall.max(1e-9),
                mean_us: m.mean_us(),
                p50_us: m.percentile_us(50.0),
                p99_us: m.percentile_us(99.0),
                iters: m.completed.load(Ordering::Relaxed) as usize,
                extra: vec![
                    ("occupancy".into(), m.occupancy()),
                    ("mem_padding_waste".into(), m.padding_waste()),
                    ("compute_waste".into(), m.compute_waste()),
                    ("compute_saved".into(), m.compute_saved()),
                    ("rows_per_sec_unmasked".into(), *unmasked_rps),
                ],
            });
        }
        gw.shutdown();
        gw_un.shutdown();
    }
    let _ = benchlib::write_bench_json("gateway", &records);
    println!("\nexpected: tail buckets (N=256) dominate latency; \
              i-clustered keeps p99 flat where full grows with N²; mem \
              waste tracks the log2-uniform mix (~30-40%) while compute \
              waste reads 0 (masking skips padded rows — the unmasked \
              column shows what that buys); ≡ unpadded must read true \
              everywhere (masking contract).");
}
