//! Figure 8 (suppl. §C.4): qualitative attention-map comparison on the
//! SQuAD-analog task — full vs clustered vs i-clustered from the SAME
//! pretrained weights, via the `attention_maps` artifact.
//!
//! Prints per-row L1 approximation errors (the quantitative core of the
//! figure), an agreement statistic on each query's argmax key, and an
//! ASCII sparkline of one query's attention row.

use clustered_transformers::benchlib::traincache::{env_usize,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::data::{glue, Split};
use clustered_transformers::runtime::{HostTensor, Runtime};

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS_GLUE", 150) as u64;

    let ckpt = match train_or_load(&rt, "glue-squad-full", steps) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("pretrain failed: {e:#}");
            return;
        }
    };
    let exe = rt
        .load("glue-squad-i-clustered-25.attention_maps")
        .expect("attention_maps artifact");

    // one real SQuAD-analog sample
    let batch = glue::span_batch(0, Split::Test, 0, 1);
    let n = batch.seq_len;
    let outputs = exe
        .run(&[
            HostTensor::F32(ckpt.params.clone()),
            HostTensor::I32(batch.x[..n].to_vec()),
            HostTensor::F32(batch.mask[..n].to_vec()),
            HostTensor::scalar_i32(0),
        ])
        .unwrap();
    let a_full = outputs[0].as_f32().unwrap();
    let a_clus = outputs[1].as_f32().unwrap();
    let a_impr = outputs[2].as_f32().unwrap();

    let l1 = |approx: &[f32]| -> (f64, f64) {
        let mut total = 0f64;
        let mut worst = 0f64;
        for i in 0..n {
            let row: f64 = (0..n)
                .map(|j| (approx[i * n + j] - a_full[i * n + j]).abs()
                     as f64)
                .sum();
            total += row;
            worst = worst.max(row);
        }
        (total / n as f64, worst)
    };
    let argmax_agree = |approx: &[f32]| -> f64 {
        let mut agree = 0usize;
        for i in 0..n {
            let am = |m: &[f32]| (0..n)
                .max_by(|&a, &b| m[i * n + a].partial_cmp(&m[i * n + b])
                        .unwrap())
                .unwrap();
            if am(approx) == am(a_full) {
                agree += 1;
            }
        }
        agree as f64 / n as f64
    };

    let (mc, wc) = l1(a_clus);
    let (mi, wi) = l1(a_impr);
    let mut tbl = Table::new(
        "fig8: attention-map approximation vs full (SQuAD-analog, layer 3)",
        &["variant", "mean row L1", "worst row L1", "argmax agreement"],
    );
    tbl.row(vec!["clustered-25".into(), format!("{mc:.3}"),
                 format!("{wc:.3}"), format!("{:.2}", argmax_agree(a_clus))]);
    tbl.row(vec!["i-clustered-25".into(), format!("{mi:.3}"),
                 format!("{wi:.3}"), format!("{:.2}", argmax_agree(a_impr))]);
    tbl.emit();

    // sparkline of a question-token row (query 1 = first needle token)
    let q = 1usize;
    println!("attention row of question token {q} (▁=0 … █=max):");
    for (name, m) in [("full", a_full), ("clustered", a_clus),
                      ("i-clustered", a_impr)] {
        let row = &m[q * n..(q + 1) * n];
        let max = row.iter().cloned().fold(0f32, f32::max).max(1e-9);
        let chars = "▁▂▃▄▅▆▇█";
        let line: String = row
            .iter()
            .step_by(2)
            .map(|&v| {
                let idx = ((v / max) * 7.0).round() as usize;
                chars.chars().nth(idx.min(7)).unwrap()
            })
            .collect();
        println!("{name:>12}: {line}");
    }
    assert!(mi <= mc + 1e-6,
            "prop 2 violated on real activations: {mi} > {mc}");
    println!("\nexpected shape (paper fig. 8): i-clustered reproduces \
              full's sparse pointer patterns; clustered smears them \
              (higher L1, lower argmax agreement).");
}
