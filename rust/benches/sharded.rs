//! Sharded fan-out bench: rows/sec vs in-process shard-worker count,
//! split/gather overhead, and tail latency under mixed ragged traffic.
//!
//! Every `ShardedBackend` here runs its workers in-process (one
//! sequential `ShardEngine` per shard, fanned out on scoped threads),
//! so the numbers isolate the split/dispatch/gather machinery from
//! network cost — the TCP transport adds wire time on top but reuses
//! exactly this planner.  Before any timing, each configuration is
//! asserted bit-identical to `NativeBackend` on the same descriptor:
//! the scaling numbers are only meaningful because the answer never
//! changes.
//!
//! Expected shape: compute-bound kernels (`full` at large N) scale
//! near-linearly to the physical core count — >= 1.7x at 2 shards on
//! the large-N bucket — then flatten once shards outnumber cores or
//! the per-shard slice gets too thin to amortise split/gather.  The
//! `shards=1` row against raw native is the overhead floor: one extra
//! tensor copy each way, no threads.  `CT_SMOKE=1` shrinks the grid
//! for CI.

use clustered_transformers::attention::{AttentionBackend, AttnBatch,
                                        NativeBackend, ShardedBackend};
use clustered_transformers::benchlib::{self, quick, rows_per_sec,
                                       BenchRecord, Table};
use clustered_transformers::config::init_logging;
use clustered_transformers::exec::ExecCtx;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::tensor::batch::BatchMatrix;

const HEADS: usize = 2;
const BATCH: usize = 8;

fn smoke() -> bool {
    std::env::var("CT_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    init_logging(false);
    let (n, d) = if smoke() { (256, 16) } else { (1024, 32) };
    let families: &[&str] =
        if smoke() { &["full"] } else { &["full", "i-clustered-8"] };
    let shard_counts = [1usize, 2, 4, 8];
    let ctx = ExecCtx::sequential();
    let seed = 0x5AAD_BE4C_u64;
    let mut records = Vec::new();

    for &kernel in families {
        let mut rng = Xoshiro256::new(seed ^ n as u64);
        let q = BatchMatrix::randn(BATCH, HEADS, n, d, &mut rng);
        let k = BatchMatrix::randn(BATCH, HEADS, n, d, &mut rng);
        let v = BatchMatrix::randn(BATCH, HEADS, n, d, &mut rng);
        let batch = AttnBatch::new(&q, &k, &v, seed);
        let rows = BATCH * n;

        let native = NativeBackend::by_name(kernel).expect("kernel");
        let want = native.execute(&batch, &ctx);
        let st_native = quick(|| {
            let _ = native.execute(&batch, &ctx);
        });
        let native_rps = rows_per_sec(rows, &st_native);

        let mut table = Table::new(
            &format!(
                "sharded[{kernel}]: B={BATCH} H={HEADS} N={n} D={d}, \
                 in-process shard workers"),
            &["shards", "rows/s", "speedup vs 1", "p99 ms",
              "overhead vs native"],
        );
        let mut base_rps = 0.0f64;
        for &shards in &shard_counts {
            let backend = ShardedBackend::in_process(kernel, shards, 1)
                .expect("kernel");
            // the contract, live: fan-out never moves bits
            let got = backend.execute(&batch, &ctx);
            assert!(got.bit_identical(&want),
                    "{kernel}/{shards} shards diverged from native");
            let st = quick(|| {
                let _ = backend.execute(&batch, &ctx);
            });
            let rps = rows_per_sec(rows, &st);
            if shards == 1 {
                base_rps = rps;
            }
            let speedup = rps / base_rps.max(1e-9);
            // shards=1 vs raw native is the pure split/gather cost
            let overhead = st.mean_s / st_native.mean_s.max(1e-12) - 1.0;
            table.row(vec![
                shards.to_string(),
                format!("{rps:.0}"),
                format!("{speedup:.2}x"),
                format!("{:.3}", st.p99_s * 1e3),
                format!("{:+.1}%", 100.0 * overhead),
            ]);
            records.push(
                BenchRecord::from_stats(
                    &format!("{kernel}/N={n}/shards={shards}"), rows, &st)
                    .with("shards", shards as f64)
                    .with("speedup_vs_1", speedup)
                    .with("efficiency", speedup / shards as f64)
                    .with("overhead_vs_native", overhead),
            );
        }
        table.emit();
        records.push(
            BenchRecord::from_stats(&format!("{kernel}/N={n}/native"),
                                    rows, &st_native)
                .with("rows_per_sec_native", native_rps),
        );

        // Mixed ragged traffic: lens spanning 1..N stress the planner's
        // per-sequence masking; p99 lands in the JSON via BenchRecord.
        let lens: Vec<usize> =
            (0..BATCH).map(|b| 1 + (b * (n - 1)) / (BATCH - 1)).collect();
        let valid: usize = lens.iter().sum();
        let ragged = AttnBatch::new(&q, &k, &v, seed).with_lens(&lens);
        let want_ragged = native.execute(&ragged, &ctx);
        let backend = ShardedBackend::in_process(kernel, 4, 1)
            .expect("kernel");
        assert!(backend.execute(&ragged, &ctx).bit_identical(&want_ragged),
                "{kernel}: ragged fan-out diverged from native");
        let st = quick(|| {
            let _ = backend.execute(&ragged, &ctx);
        });
        let mut mixed = Table::new(
            &format!("sharded[{kernel}]: mixed ragged traffic, 4 shards"),
            &["valid rows", "rows/s", "p50 ms", "p99 ms"],
        );
        mixed.row(vec![
            format!("{valid}/{}", BATCH * n),
            format!("{:.0}", rows_per_sec(valid, &st)),
            format!("{:.3}", st.p50_s * 1e3),
            format!("{:.3}", st.p99_s * 1e3),
        ]);
        mixed.emit();
        records.push(
            BenchRecord::from_stats(&format!("{kernel}/N={n}/mixed-4"),
                                    valid, &st)
                .with("shards", 4.0)
                .with("valid_rows", valid as f64),
        );
    }

    let _ = benchlib::write_bench_json("sharded", &records);
    println!("\nexpected: full/N={n} reaches >= 1.7x rows/sec at 2 shards \
              (compute-bound O(N^2) slices dwarf the one copy each way), \
              scaling flattens past the core count; shards=1 vs native \
              is the split/gather floor (single-digit % at large N); \
              ragged traffic keeps p99 close to p50 because the planner \
              balances sequences, not padded rows.");
}
