//! Figure 1: speed–accuracy trade-off under an equalized computational
//! budget (WSJ-analog 1a and Switchboard-analog 1b).
//!
//! Each point = one trained model: x = forward-pass wall time of its
//! compiled artifact, y = PER on held-out data.  Training effort is
//! CT_STEPS (default 60; the paper trained to convergence for days —
//! EXPERIMENTS.md records the scaling caveat).  CT_FULL=1 expands to the
//! full variant grid.

use clustered_transformers::attention::{self, AttnBatch, Variant};
use clustered_transformers::benchlib::traincache::{
    env_usize, eval_score, forward_time, full_grid, train_or_load,
};
use clustered_transformers::benchlib::{self, BenchRecord, Table};
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::exec::{ExecCtx, WorkerPool};
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::runtime::Runtime;
use clustered_transformers::tensor::batch::BatchMatrix;

/// Native batched multi-head speed-vs-approximation frontier — the fig. 1
/// trade-off axis measured on the kernel engine itself, so the bench
/// reports something even before `make artifacts`.
fn native_frontier() {
    let (bsz, heads, n, dk) = (2usize, 4usize, 512usize, 64usize);
    let ctx = ExecCtx::new(WorkerPool::auto());
    let mut rng = Xoshiro256::new(0);
    let q = BatchMatrix::randn(bsz, heads, n, dk, &mut rng);
    let k = BatchMatrix::randn(bsz, heads, n, dk, &mut rng);
    let v = BatchMatrix::randn(bsz, heads, n, dk, &mut rng);
    let exact = attention::kernel_for(&Variant::Full)
        .solve_batch(&AttnBatch::new(&q, &k, &v, 0), &ctx);
    let rows = bsz * heads * n;
    let mut tbl = Table::new(
        &format!("fig1c: native batched engine frontier, B={bsz} \
                  H={heads} N={n} Dk={dk}, pool={} workers",
                 ctx.workers()),
        &["variant", "ms/batch", "rows/s", "max|Δ| vs full"],
    );
    let mut records = Vec::new();
    let variants = [
        Variant::Full,
        Variant::Clustered { clusters: 100, bits: 63, iters: 10 },
        Variant::ImprovedClustered { clusters: 100, bits: 63, iters: 10,
                                     topk: 32 },
        Variant::Lsh { rounds: 1, chunk: 32 },
        Variant::Lsh { rounds: 4, chunk: 32 },
    ];
    for var in &variants {
        let kernel = attention::kernel_for(var);
        let batch = AttnBatch::new(&q, &k, &v, 0);
        let out = kernel.solve_batch(&batch, &ctx);
        let st = benchlib::bench(
            || { let _ = kernel.solve_batch(&batch, &ctx); },
            1, 2, std::time::Duration::from_millis(300), 8);
        tbl.row(vec![
            var.name(),
            format!("{:.1}", st.mean_ms()),
            format!("{:.0}", benchlib::rows_per_sec(rows, &st)),
            format!("{:.3}", out.max_abs_diff(&exact)),
        ]);
        records.push(
            BenchRecord::from_stats(&var.name(), rows, &st)
                .with("max_abs_diff_vs_full",
                      out.max_abs_diff(&exact) as f64));
    }
    tbl.emit();
    let _ = benchlib::write_bench_json("fig1_tradeoff", &records);
}

fn main() {
    init_logging(false);
    native_frontier();
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; HLO speed-accuracy points skipped (run \
                   `make artifacts`)");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS", 60) as u64;

    let mut wsj: Vec<&str> = vec![
        "wsj-l2-full", "wsj-l4-full", "wsj-l6-full",
        "wsj-l6-clustered-25", "wsj-l6-i-clustered-25", "wsj-l6-lsh-1",
    ];
    if full_grid() {
        wsj.extend(["wsj-l6-clustered-50", "wsj-l6-clustered-75",
                    "wsj-l6-i-clustered-50", "wsj-l4-i-clustered-25",
                    "wsj-l4-i-clustered-50", "wsj-l6-lsh-4"]);
    }
    let mut swb: Vec<&str> = vec![
        "swb-l2-full", "swb-l6-full", "swb-l6-clustered-25",
        "swb-l6-i-clustered-25",
    ];
    if full_grid() {
        swb.extend(["swb-l4-full", "swb-l6-i-clustered-50"]);
    }

    for (fig, models) in [("fig1a: WSJ-analog speed-accuracy", &wsj),
                          ("fig1b: SWB-analog speed-accuracy", &swb)] {
        let mut tbl = Table::new(
            fig, &["model", "fwd ms/batch", "PER%", "train s/step"]);
        for model in models.iter() {
            match run_point(&rt, model, steps) {
                Ok(row) => tbl.row(row),
                Err(e) => eprintln!("  {model}: {e:#}"),
            }
        }
        tbl.emit();
    }
    println!("expected shape (paper fig. 1): i-clustered dominates the \
              budget frontier;\nclustered is fastest-but-coarser; full \
              needs more layers (time) for the same PER.");
}

fn run_point(rt: &Runtime, model: &str, steps: u64)
             -> anyhow::Result<Vec<String>> {
    let ckpt = train_or_load(rt, model, steps)?;
    let fwd = format!("{model}.forward");
    let t = forward_time(rt, &fwd, &ckpt.params, 3)?;
    let score = eval_score(rt, &fwd, &ckpt.params, 4)?;
    let sps = ckpt.meta.get("seconds_per_step").as_f64().unwrap_or(0.0);
    Ok(vec![model.to_string(), format!("{:.1}", t * 1e3),
            format!("{:.1}", score.value), format!("{sps:.2}")])
}
