//! Compute-core bench: the tiled parallel substrate measured head to
//! head against the paths it replaced.
//!
//!  1. **GEMM** — naive i-k-j loop vs blocked panel-packed kernel
//!    (sequential and row-partitioned over the pool), NN and NT, with a
//!    bit-identical column (the determinism contract is part of the
//!    measurement).
//!  2. **Softmax attention** — materialized O(N²) logits vs the
//!    streaming online-max path, up to N = 4096, where the dense path
//!    allocates a 64 MB logits matrix per head and the streaming path
//!    touches O(N·block).  Peak-RSS is sampled after each stage.
//!  3. **LSH hashing** — the seed's N·bits scalar dots vs the one-shot
//!    `(N×D)·(D×bits)` GEMM + sign bit-packing.
//!
//! Writes `BENCH_compute_core.json` at the repo root
//! (`benchlib::write_bench_json` schema).  `CT_SMOKE=1` shrinks every
//! dimension so CI can compile-and-run the perf path in seconds.

use std::time::Duration;

use clustered_transformers::attention::full::{
    full_attention_materialized, streaming_softmax_attention,
};
use clustered_transformers::benchlib::{self, BenchRecord, Table};
use clustered_transformers::clustering::Lsh;
use clustered_transformers::config::init_logging;
use clustered_transformers::exec::{ExecCtx, WorkerPool};
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::tensor::{dot, gemm, Matrix};

fn smoke() -> bool {
    std::env::var("CT_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn bench_quick<F: FnMut()>(f: F) -> benchlib::Stats {
    let (min_iters, max_iters, min_time) = if smoke() {
        (1, 2, Duration::from_millis(0))
    } else {
        (3, 12, Duration::from_millis(400))
    };
    benchlib::bench(f, 1, min_iters, min_time, max_iters)
}

fn bits_eq(a: &Matrix, b: &Matrix) -> bool {
    a.bit_identical(b)
}

fn gemm_section(ctx: &ExecCtx, records: &mut Vec<BenchRecord>) {
    let shapes: &[(usize, usize, usize, &str)] = if smoke() {
        &[(96, 64, 96, "nt"), (96, 96, 64, "nn")]
    } else {
        &[
            (512, 64, 512, "nt"),    // Q·Kᵀ logits shape
            (1024, 64, 1024, "nt"),  // longer-N logits
            (1024, 1024, 64, "nn"),  // A·V shape
            (100, 4096, 64, "nn"),   // centroid A^c·V shape
        ]
    };
    let mut tbl = Table::new(
        &format!("compute-core GEMM: naive vs blocked vs blocked+pool \
                  ({} workers)", ctx.workers()),
        &["shape", "naive ms", "blocked ms", "pool ms", "GFLOP/s pool",
          "bit-identical"],
    );
    let mut rng = Xoshiro256::new(1);
    for &(m, k, n, kind) in shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let (b, naive, blocked): (Matrix, fn(&Matrix, &Matrix) -> Matrix,
                                  fn(&Matrix, &Matrix, &ExecCtx) -> Matrix) =
            if kind == "nn" {
                (Matrix::randn(k, n, &mut rng), gemm::naive_nn,
                 gemm::matmul_nn)
            } else {
                (Matrix::randn(n, k, &mut rng), gemm::naive_nt,
                 gemm::matmul_nt)
            };
        let st_naive = bench_quick(|| { let _ = naive(&a, &b); });
        let seq = ExecCtx::sequential();
        let st_blocked = bench_quick(|| { let _ = blocked(&a, &b, &seq); });
        let st_pool = bench_quick(|| { let _ = blocked(&a, &b, ctx); });
        let identical = bits_eq(&naive(&a, &b), &blocked(&a, &b, ctx));
        let gflops =
            (m as f64 * k as f64 * n as f64) / st_pool.mean_s.max(1e-12)
                / 1e9;
        let label = format!("gemm-{kind}-{m}x{k}x{n}");
        tbl.row(vec![
            label.clone(),
            format!("{:.2}", st_naive.mean_ms()),
            format!("{:.2}", st_blocked.mean_ms()),
            format!("{:.2}", st_pool.mean_ms()),
            format!("{gflops:.2}"),
            identical.to_string(),
        ]);
        records.push(
            BenchRecord::from_stats(&label, m, &st_pool)
                .with("naive_ms", st_naive.mean_ms())
                .with("blocked_seq_ms", st_blocked.mean_ms())
                .with("gflops", gflops)
                .with("bit_identical", identical as u8 as f64));
    }
    tbl.emit();
}

/// The acceptance demo: long-N full attention through the streaming
/// path, with its RSS growth measured.  Must run before ANY other
/// section — VmHWM is a process-wide high-water mark, so dense N×N (or
/// large GEMM) work beforehand would raise the mark and hide a
/// streaming memory regression entirely.
fn streaming_memory_demo(ctx: &ExecCtx, records: &mut Vec<BenchRecord>) {
    let n = if smoke() { 1024 } else { 4096 };
    let mut r = Xoshiro256::new(3);
    let q = Matrix::randn(n, 64, &mut r);
    let k = Matrix::randn(n, 64, &mut r);
    let v = Matrix::randn(n, 64, &mut r);
    let before = benchlib::peak_rss_bytes();
    let out = streaming_softmax_attention(&q, &k, &v, 0.125, ctx);
    let grown = benchlib::peak_rss_bytes().saturating_sub(before);
    println!("streaming full attention N={n}: out {}x{}, peak-RSS grew \
              {:.1} MB (an N×N f32 matrix alone would be {:.0} MB)",
             out.rows, out.cols, grown as f64 / (1024.0 * 1024.0),
             (n * n * 4) as f64 / (1024.0 * 1024.0));
    records.push(
        BenchRecord::from_stats(&format!("softmax-stream-demo-n{n}"), n,
                                &benchlib::Stats::from_samples(&[]))
            .with("rss_growth_mb", grown as f64 / (1024.0 * 1024.0))
            .with("dense_logits_mb",
                  (n * n * 4) as f64 / (1024.0 * 1024.0)));
}

fn softmax_section(ctx: &ExecCtx, records: &mut Vec<BenchRecord>) {
    let (ns, d): (&[usize], usize) =
        if smoke() { (&[256], 32) } else { (&[1024, 2048, 4096], 64) };
    let mut tbl = Table::new(
        "compute-core softmax attention: materialized N×N vs streaming \
         O(N·block)",
        &["N", "materialized ms", "stream ms", "stream+pool ms",
          "max|Δ|", "RSS hwm MB"],
    );
    let mut rng = Xoshiro256::new(2);
    for &n in ns {
        let q = Matrix::randn(n, d, &mut rng);
        let k = Matrix::randn(n, d, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let scale = 1.0 / (d as f32).sqrt();
        let seq = ExecCtx::sequential();
        // the dense path past N=2048 exists to show exactly what the
        // streaming path avoids; one timed run is enough
        // dense timings come after the demo above on purpose: the
        // materialized N×N run permanently raises the RSS high-water
        // mark, so the table's RSS column reads as the process hwm
        // (monotone), not a per-stage attribution
        let st_mat = bench_quick(
            || { let _ = full_attention_materialized(&q, &k, &v); });
        let st_stream = bench_quick(
            || { let _ = streaming_softmax_attention(&q, &k, &v, scale,
                                                     &seq); });
        let st_pool = bench_quick(
            || { let _ = streaming_softmax_attention(&q, &k, &v, scale,
                                                     ctx); });
        let diff = streaming_softmax_attention(&q, &k, &v, scale, ctx)
            .max_abs_diff(&full_attention_materialized(&q, &k, &v));
        let rss_mb = benchlib::peak_rss_bytes() as f64 / (1024.0 * 1024.0);
        tbl.row(vec![
            n.to_string(),
            format!("{:.1}", st_mat.mean_ms()),
            format!("{:.1}", st_stream.mean_ms()),
            format!("{:.1}", st_pool.mean_ms()),
            format!("{diff:.2e}"),
            format!("{rss_mb:.0}"),
        ]);
        records.push(
            BenchRecord::from_stats(&format!("softmax-stream-n{n}"), n,
                                    &st_pool)
                .with("materialized_ms", st_mat.mean_ms())
                .with("stream_seq_ms", st_stream.mean_ms())
                .with("max_abs_diff", diff as f64)
                .with("peak_rss_mb", rss_mb));
    }
    tbl.emit();
}

fn lsh_section(ctx: &ExecCtx, records: &mut Vec<BenchRecord>) {
    let (n, d, bits) = if smoke() { (2048, 32, 63) } else {
        (32768, 64, 63)
    };
    let mut rng = Xoshiro256::new(4);
    let lsh = Lsh::new(d, bits, &mut rng);
    let x = Matrix::randn(n, d, &mut rng);
    // the seed path: N·bits separate scalar dots
    let scalar_hash = || {
        let mut codes =
            clustered_transformers::clustering::BitCodes::new(n, bits);
        for i in 0..n {
            for b in 0..bits {
                if dot(x.row(i), lsh.proj.row(b)) >= 0.0 {
                    codes.set_bit(i, b);
                }
            }
        }
        codes
    };
    let st_scalar = bench_quick(|| { let _ = scalar_hash(); });
    let seq = ExecCtx::sequential();
    let st_gemm = bench_quick(|| { let _ = lsh.hash_ctx(&x, &seq); });
    let st_pool = bench_quick(|| { let _ = lsh.hash_ctx(&x, ctx); });
    // summation order differs between dot() and the GEMM, so a sign can
    // flip only when a projection lands within float noise of zero
    let (a, b) = (scalar_hash(), lsh.hash_ctx(&x, ctx));
    let flipped: u32 = a.words.iter().zip(&b.words)
        .map(|(x, y)| (x ^ y).count_ones())
        .sum();
    let mut tbl = Table::new(
        &format!("compute-core LSH hash: N={n} D={d} bits={bits}"),
        &["path", "ms", "Mcodes/s"],
    );
    for (name, st) in [("scalar dots", &st_scalar),
                       ("gemm", &st_gemm), ("gemm+pool", &st_pool)] {
        tbl.row(vec![
            name.into(),
            format!("{:.2}", st.mean_ms()),
            format!("{:.2}", n as f64 / st.mean_s.max(1e-12) / 1e6),
        ]);
    }
    tbl.emit();
    println!("  sign flips vs scalar path: {flipped} of {} bits",
             n * bits);
    records.push(
        BenchRecord::from_stats("lsh-hash-gemm-pool", n, &st_pool)
            .with("scalar_ms", st_scalar.mean_ms())
            .with("gemm_seq_ms", st_gemm.mean_ms())
            .with("sign_flips", flipped as f64));
}

fn main() {
    init_logging(false);
    let ctx = ExecCtx::new(WorkerPool::auto());
    let mut records = Vec::new();
    // RSS demo first: every later section raises the VmHWM mark
    streaming_memory_demo(&ctx, &mut records);
    gemm_section(&ctx, &mut records);
    softmax_section(&ctx, &mut records);
    lsh_section(&ctx, &mut records);
    let _ = benchlib::write_bench_json("compute_core", &records);
    println!("\nexpected: blocked GEMM beats naive by cache effects alone, \
              pool adds ~workers× on large shapes;\nstreaming softmax \
              matches materialized within float noise while its memory \
              stays flat in N;\nbit-identical must read true everywhere \
              (partition rows, never split reductions).");
}
