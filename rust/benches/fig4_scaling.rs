//! Figure 4 (+ suppl. §C.1): per-element time and memory vs sequence
//! length for every attention variant.
//!
//! Paper setup: N = 2^9..2^15 on a 1080 Ti, per-element GPU time/memory.
//! Here: the Rust-native single-head implementations sweep the same N
//! range on CPU (the asymptotic *shape* — quadratic vs linear, crossover
//! location — is hardware-independent), the analytic cost model supplies
//! the memory column, the batched multi-head engine reports (B, H, N, D)
//! rows/sec through the exec pool, and compiled single-layer HLO
//! forwards cross-check the trend at N ∈ {256, 512, 1024}.

use clustered_transformers::attention::{self, AttnBatch, AttnProblem,
                                        Variant};
use clustered_transformers::benchlib::{self, BenchRecord, Table};
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::exec::{ExecCtx, WorkerPool};
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::runtime::{HostTensor, Runtime};
use clustered_transformers::tensor::batch::BatchMatrix;
use clustered_transformers::tensor::Matrix;

fn variants() -> Vec<Variant> {
    vec![
        Variant::Full,
        Variant::Clustered { clusters: 100, bits: 63, iters: 10 },
        Variant::ImprovedClustered { clusters: 100, bits: 63, iters: 10,
                                     topk: 32 },
        Variant::Lsh { rounds: 1, chunk: 32 },
        Variant::Lsh { rounds: 4, chunk: 32 },
    ]
}

fn main() {
    init_logging(false);
    let dk = 64;
    let max_pow = if benchlib::traincache::full_grid() { 15 } else { 13 };

    // --- native sweep: per-element µs --------------------------------
    let mut time_tbl = Table::new(
        "fig4a: per-element time (µs) vs N — native single head, Dk=64",
        &["N", "full", "clustered-100", "i-clustered-100", "lsh-1",
          "lsh-4"],
    );
    let mut mem_tbl = Table::new(
        "fig4b: per-element working-set bytes vs N (analytic cost model)",
        &["N", "full", "clustered-100", "i-clustered-100", "lsh-1",
          "lsh-4"],
    );
    for pow in 9..=max_pow {
        let n = 1usize << pow;
        let mut rng = Xoshiro256::new(0);
        let q = Matrix::randn(n, dk, &mut rng);
        let k = Matrix::randn(n, dk, &mut rng);
        let v = Matrix::randn(n, dk, &mut rng);
        let mut trow = vec![n.to_string()];
        let mut mrow = vec![n.to_string()];
        for var in variants() {
            // full attention above 2^13 is minutes on CPU — extrapolate
            // (the paper's GPU had the same problem: OOM past 2^13)
            let per_el_us = if matches!(var, Variant::Full
                                        | Variant::Lsh { rounds: 4, .. })
                && n > (1 << 12)
            {
                f64::NAN
            } else {
                let mut r = Xoshiro256::new(1);
                let seq = ExecCtx::sequential();
                let st = benchlib::bench(
                    || {
                        let p = AttnProblem::new(&q, &k, &v);
                        let _ = attention::solve(&var, &p, &mut r, &seq);
                    },
                    1, 2, std::time::Duration::from_millis(300), 10);
                st.mean_us() / n as f64
            };
            trow.push(if per_el_us.is_nan() { "oom/skip".into() }
                      else { format!("{per_el_us:.2}") });
            let cost = attention::cost_model(&var, n, dk, dk);
            mrow.push(format!("{:.0}", cost.bytes as f64 / n as f64));
        }
        time_tbl.row(trow);
        mem_tbl.row(mrow);
    }
    time_tbl.emit();
    mem_tbl.emit();

    // --- batched multi-head engine: rows/sec through the exec pool ---
    let (bsz, heads, n_b) = (4usize, 4usize, 512usize);
    let pool = ExecCtx::new(WorkerPool::auto());
    let seq = ExecCtx::sequential();
    let mut batch_tbl = Table::new(
        &format!(
            "fig4c: batched multi-head throughput (rows/sec), B={bsz} \
             H={heads} N={n_b} Dk={dk}, pool={} workers",
            pool.workers()
        ),
        &["variant", "seq ms/batch", "par ms/batch", "seq rows/s",
          "par rows/s", "pool speedup", "bit-identical"],
    );
    let mut records = Vec::new();
    let mut brng = Xoshiro256::new(2);
    let bq = BatchMatrix::randn(bsz, heads, n_b, dk, &mut brng);
    let bk = BatchMatrix::randn(bsz, heads, n_b, dk, &mut brng);
    let bv = BatchMatrix::randn(bsz, heads, n_b, dk, &mut brng);
    let rows = bsz * heads * n_b;
    for var in variants() {
        let kernel = attention::kernel_for(&var);
        let batch = AttnBatch::new(&bq, &bk, &bv, 0);
        let st_seq = benchlib::bench(
            || { let _ = kernel.solve_batch(&batch, &seq); },
            1, 2, std::time::Duration::from_millis(300), 8);
        let st_par = benchlib::bench(
            || { let _ = kernel.solve_batch(&batch, &pool); },
            1, 2, std::time::Duration::from_millis(300), 8);
        // determinism contract: pool schedule must not change the bits
        let identical = kernel
            .solve_batch(&batch, &pool)
            .bit_identical(&attention::solve_batch_seq(kernel.as_ref(),
                                                       &batch));
        batch_tbl.row(vec![
            var.name(),
            format!("{:.1}", st_seq.mean_ms()),
            format!("{:.1}", st_par.mean_ms()),
            format!("{:.0}", benchlib::rows_per_sec(rows, &st_seq)),
            format!("{:.0}", benchlib::rows_per_sec(rows, &st_par)),
            format!("{:.2}x", st_seq.mean_s / st_par.mean_s.max(1e-12)),
            identical.to_string(),
        ]);
        records.push(
            BenchRecord::from_stats(&var.name(), rows, &st_par)
                .with("seq_rows_per_sec",
                      benchlib::rows_per_sec(rows, &st_seq))
                .with("pool_speedup",
                      st_seq.mean_s / st_par.mean_s.max(1e-12))
                .with("bit_identical", identical as u8 as f64));
    }
    batch_tbl.emit();
    let _ = benchlib::write_bench_json("fig4_scaling", &records);

    // --- HLO cross-check: compiled single-layer forward --------------
    let dir = find_repo_root().join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = match Runtime::open(dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("runtime unavailable, HLO section skipped: {e:#}");
                return;
            }
        };
        let mut tbl = Table::new(
            "fig4d: compiled 1-layer transformer forward (HLO/PJRT), ms",
            &["N", "full", "clustered-25", "i-clustered-25", "lsh-1"],
        );
        for n in [256usize, 512, 1024] {
            let mut row = vec![n.to_string()];
            for var in ["full", "clustered-25", "i-clustered-25", "lsh-1"] {
                let name = format!("layer-n{n}-{var}.forward");
                match rt.load(&name) {
                    Ok(exe) => {
                        let p = &exe.program;
                        let x = HostTensor::I32(vec![1; p.batch_size() * n]);
                        let params = HostTensor::F32(
                            vec![0.01; p.param_count]);
                        let inputs = vec![params, x,
                                          HostTensor::scalar_i32(0)];
                        exe.run(&inputs).unwrap();
                        let st = benchlib::bench(
                            || { exe.run(&inputs).unwrap(); },
                            0, 3, std::time::Duration::from_millis(300),
                            10);
                        row.push(format!("{:.1}", st.mean_ms()));
                    }
                    Err(_) => row.push("-".into()),
                }
            }
            tbl.row(row);
        }
        tbl.emit();
    } else {
        eprintln!("(no artifacts; HLO cross-check skipped)");
    }
    println!("expected shape (paper fig. 4): full grows ~linearly per \
              element (quadratic total);\nclustered variants flat per \
              element (linear total); crossover near N≈1–2k.");
}
