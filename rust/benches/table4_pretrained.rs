//! Table 4: approximate a pretrained full-attention model with 25
//! clusters on the GLUE/SQuAD-analog tasks — no retraining, the flat
//! checkpoint is executed under each variant's forward artifact.

use clustered_transformers::benchlib::traincache::{env_usize, eval_score,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::runtime::Runtime;

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS_GLUE", 150) as u64;

    let tasks = ["sst2", "mrpc", "qnli", "rte", "squad"];
    let mut tbl = Table::new(
        "table4: pretrained-full served with clustered attention \
         (GLUE/SQuAD-analog)",
        &["evaluate with", "sst2", "mrpc", "qnli", "rte", "squad(F1)"],
    );

    // pretrain each task once with full attention
    let mut ckpts = Vec::new();
    for t in &tasks {
        match train_or_load(&rt, &format!("glue-{t}-full"), steps) {
            Ok(c) => ckpts.push(Some(c)),
            Err(e) => {
                eprintln!("  glue-{t}-full: {e:#}");
                ckpts.push(None);
            }
        }
    }

    for variant in ["full", "clustered-25", "i-clustered-25"] {
        let mut row = vec![variant.to_string()];
        for (ti, t) in tasks.iter().enumerate() {
            let cell = match &ckpts[ti] {
                Some(ckpt) => {
                    let fwd = format!("glue-{t}-{variant}.forward");
                    match eval_score(&rt, &fwd, &ckpt.params, 6) {
                        Ok(s) => format!("{:.3}", s.value),
                        Err(_) => "-".into(),
                    }
                }
                None => "-".into(),
            };
            row.push(cell);
        }
        tbl.row(row);
    }
    tbl.emit();
    println!("expected shape (paper table 4): i-clustered-25 ≈ full on \
              every task;\nclustered-25 collapses on the sparse-attention \
              tasks (squad, rte-like).");
}
