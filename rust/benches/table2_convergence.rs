//! Table 2: WSJ-analog convergence economics — test PER, time per epoch
//! and wall-clock time to (early-stop) convergence for the 6-layer
//! variants.  An "epoch" here is a fixed 50-step pass (synthetic corpus =
//! infinite sampler), matching relative comparisons, not absolute hours.

use clustered_transformers::benchlib::traincache::{env_usize, eval_score,
                                                   full_grid,
                                                   train_or_load};
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::runtime::Runtime;

const STEPS_PER_EPOCH: f64 = 50.0;

fn main() {
    init_logging(false);
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    }
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable, HLO section skipped: {e:#}");
            return;
        }
    };
    let steps = env_usize("CT_STEPS", 60) as u64;

    let mut variants: Vec<&str> =
        vec!["full", "lsh-1", "clustered-25", "i-clustered-25"];
    if full_grid() {
        variants.push("lsh-4");
    }

    let mut tbl = Table::new(
        "table2: WSJ-analog convergence (6 layers)",
        &["variant", "test PER%", "s/epoch (50 steps)",
          "best-val wall s", "total wall s"],
    );
    for v in &variants {
        let model = format!("wsj-l6-{v}");
        match train_or_load(&rt, &model, steps) {
            Ok(ckpt) => {
                let sps = ckpt.meta.get("seconds_per_step").as_f64()
                    .unwrap_or(0.0);
                let wall = ckpt.meta.get("wall_seconds").as_f64()
                    .unwrap_or(0.0);
                // wall time until the best validation loss was reached
                let best_step = best_val_step(&ckpt.meta);
                let best_wall = sps * best_step;
                let per = eval_score(&rt, &format!("{model}.forward"),
                                     &ckpt.params, 3)
                    .map(|s| format!("{:.1}", s.value))
                    .unwrap_or_else(|_| "-".into());
                tbl.row(vec![v.to_string(), per,
                             format!("{:.1}", sps * STEPS_PER_EPOCH),
                             format!("{best_wall:.1}"),
                             format!("{wall:.1}")]);
            }
            Err(e) => eprintln!("  {model}: {e:#}"),
        }
    }
    tbl.emit();
    println!("expected shape (paper table 2): clustered ≈ 3× faster/epoch \
              than full, i-clustered ≈ 2×;\ni-clustered alone beats full \
              on total wall-clock to a given quality.");
}

fn best_val_step(meta: &clustered_transformers::jsonio::Value) -> f64 {
    let mut best = (f64::INFINITY, 0.0);
    if let Some(arr) = meta.get("val_curve").as_arr() {
        for pair in arr {
            if let Some(p) = pair.as_arr() {
                let (s, l) = (p[0].as_f64().unwrap_or(0.0),
                              p[1].as_f64().unwrap_or(f64::INFINITY));
                if l < best.0 {
                    best = (l, s);
                }
            }
        }
    }
    best.1
}
