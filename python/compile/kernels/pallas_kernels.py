"""Layer-1 Pallas kernels for clustered attention.

Four kernels cover the paper's compute hot-spots:

  1. ``flash_attention``        — streaming-softmax vanilla attention
                                  (the O(N²) `full` baseline, tiled so the
                                  working set fits VMEM).
  2. ``centroid_sums``          — segment-sum of queries into clusters
                                  (eq. 3), expressed as a one-hot matmul so
                                  it maps onto the MXU.
  3. ``centroid_attention``     — A^c = softmax(Q^c Kᵀ) and V̂^c = A^c V
                                  (eqs. 4–5) for a block of centroids.
  4. ``topk_refine``            — the exact top-k dot products of eq. (10),
                                  rescaled by the captured mass m̂.
  5. ``hamming_assign``         — K-Means assignment step over ±1 LSH codes
                                  (Hamming distance as an MXU matmul).

TPU adaptation notes (DESIGN.md §3): the original CUDA kernels use packed
bits + ``__popc`` and thread-block gathers; here Hamming distance is a ±1
matmul (systolic-array friendly) and per-cluster gathers happen at the XLA
level so kernels see dense contiguous tiles.

These kernels MUST run with ``interpret=True`` in this environment: the
CPU PJRT plugin cannot execute Mosaic custom-calls.  Correctness is proven
against ``ref.py``; TPU performance is estimated analytically
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9
INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _pad_to(x, multiple, axis, value=0.0):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


# ---------------------------------------------------------------------------
# 1. flash attention (full baseline)
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k, scale):
    """One query block vs. all key blocks with online softmax.

    VMEM working set: q block (Bq×Dk) + one K/V tile (Bk×D) + accumulators
    (Bq×Dv + 2·Bq).  The fori_loop is the HBM→VMEM key-stream schedule that
    a CUDA implementation would express with threadblock tiling.
    """
    q = q_ref[...].astype(jnp.float32)
    bq = q.shape[0]
    dv = v_ref.shape[-1]
    n_keys = k_ref.shape[0]

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dv), jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        ks = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        vs = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        mk = pl.load(mask_ref, (pl.dslice(i * block_k, block_k),))
        s = q @ ks.T.astype(jnp.float32) * scale             # (bq, bk)
        s = jnp.where(mk[None, :] > 0, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ vs.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = lax.fori_loop(0, n_keys // block_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, key_mask=None, *, block_q=64, block_k=64):
    """Vanilla attention via the streaming kernel.  Drop-in for
    ``ref.full_attention``."""
    n, dk = q.shape
    dv = v.shape[-1]
    if key_mask is None:
        key_mask = jnp.ones((k.shape[0],), q.dtype)
    block_q = min(block_q, max(8, n))
    block_k = min(block_k, max(8, k.shape[0]))

    qp = _pad_to(q, block_q, 0)
    kp = _pad_to(k, block_k, 0)
    vp = _pad_to(v, block_k, 0)
    mp = _pad_to(key_mask.astype(q.dtype), block_k, 0)
    npad, nk = qp.shape[0], kp.shape[0]

    kernel = functools.partial(_flash_kernel, block_k=block_k,
                               scale=1.0 / (dk ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(npad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, dk), lambda i: (i, 0)),
            pl.BlockSpec((nk, dk), lambda i: (0, 0)),
            pl.BlockSpec((nk, dv), lambda i: (0, 0)),
            pl.BlockSpec((nk,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, dv), q.dtype),
        interpret=INTERPRET,
    )(qp, kp, vp, mp)
    return out[:n]


# ---------------------------------------------------------------------------
# 2. centroid sums (eq. 3) — segment sum as one-hot matmul
# ---------------------------------------------------------------------------

def _centroid_sum_kernel(q_ref, g_ref, pm_ref, sum_ref, cnt_ref, *,
                         n_clusters):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q = q_ref[...].astype(jnp.float32)
    g = g_ref[...]
    pm = pm_ref[...].astype(jnp.float32)
    oh = jax.nn.one_hot(g, n_clusters, dtype=jnp.float32) * pm[:, None]
    sum_ref[...] += (oh.T @ q).astype(sum_ref.dtype)           # MXU matmul
    cnt_ref[...] += oh.sum(axis=0).astype(cnt_ref.dtype)


def centroid_sums(q, groups, n_clusters, point_mask=None, *, block_n=128):
    """Per-cluster (sum, count); callers divide for the mean (eq. 3)."""
    n, dk = q.shape
    if point_mask is None:
        point_mask = jnp.ones((n,), q.dtype)
    block_n = min(block_n, max(8, n))
    qp = _pad_to(q, block_n, 0)
    gp = _pad_to(groups.astype(jnp.int32), block_n, 0)
    pp = _pad_to(point_mask.astype(q.dtype), block_n, 0)  # pads vote 0

    kernel = functools.partial(_centroid_sum_kernel, n_clusters=n_clusters)
    sums, counts = pl.pallas_call(
        kernel,
        grid=(qp.shape[0] // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dk), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((n_clusters, dk), lambda i: (0, 0)),
            pl.BlockSpec((n_clusters,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_clusters, dk), q.dtype),
            jax.ShapeDtypeStruct((n_clusters,), q.dtype),
        ],
        interpret=INTERPRET,
    )(qp, gp, pp)
    return sums, counts


# ---------------------------------------------------------------------------
# 3. centroid attention (eqs. 4–5)
# ---------------------------------------------------------------------------

def _centroid_attention_kernel(c_ref, k_ref, v_ref, mask_ref, a_ref, o_ref,
                               *, scale):
    """A block of centroid rows attends to ALL keys.

    C ≪ N, so materialising the (Bc × N) attention rows is exactly the
    algorithm's stated O(N·C) cost — this is not a shortcut.  Both A^c and
    V̂^c come out of one pass so K is read from VMEM once.
    """
    c = c_ref[...].astype(jnp.float32)
    ks = k_ref[...].astype(jnp.float32)
    vs = v_ref[...].astype(jnp.float32)
    mk = mask_ref[...]
    s = c @ ks.T * scale                                      # (Bc, N)
    s = jnp.where(mk[None, :] > 0, s, NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    a = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    a_ref[...] = a.astype(a_ref.dtype)
    o_ref[...] = (a @ vs).astype(o_ref.dtype)


def centroid_attention(centroids, k, v, key_mask=None, *, block_c=32):
    """Returns ``(A^c (C, N), V̂^c (C, Dv))``."""
    cdim, dk = centroids.shape
    n, dv = v.shape
    if key_mask is None:
        key_mask = jnp.ones((n,), centroids.dtype)
    block_c = min(block_c, max(8, cdim))
    cp = _pad_to(centroids, block_c, 0)

    kernel = functools.partial(_centroid_attention_kernel,
                               scale=1.0 / (dk ** 0.5))
    a_c, v_c = pl.pallas_call(
        kernel,
        grid=(cp.shape[0] // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, dk), lambda i: (i, 0)),
            pl.BlockSpec((n, dk), lambda i: (0, 0)),
            pl.BlockSpec((n, dv), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_c, n), lambda i: (i, 0)),
            pl.BlockSpec((block_c, dv), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cp.shape[0], n), centroids.dtype),
            jax.ShapeDtypeStruct((cp.shape[0], dv), centroids.dtype),
        ],
        interpret=INTERPRET,
    )(cp, k, v, key_mask.astype(centroids.dtype))
    return a_c[:cdim], v_c[:cdim]


# ---------------------------------------------------------------------------
# 4. top-k refinement (eq. 10 / suppl. 15–17)
# ---------------------------------------------------------------------------

def _topk_refine_kernel(q_ref, kg_ref, vg_ref, mhat_ref, valid_ref, vb_ref,
                        o_ref, *, scale):
    """Exact attention of each query against its cluster's top-k keys.

    The XLA level gathers K/V rows for each query's cluster beforehand, so
    this kernel sees dense (Bn × k × D) tiles — the TPU answer to the
    paper's warp-level gathers.
    """
    q = q_ref[...].astype(jnp.float32)                        # (bn, d)
    kg = kg_ref[...].astype(jnp.float32)                      # (bn, t, d)
    vg = vg_ref[...].astype(jnp.float32)                      # (bn, t, dv)
    mhat = mhat_ref[...].astype(jnp.float32)                  # (bn,)
    valid = valid_ref[...]                                    # (bn, t)
    vb = vb_ref[...].astype(jnp.float32)                      # (bn, dv)

    dots = jnp.einsum("nd,ntd->nt", q, kg) * scale
    dots = jnp.where(valid > 0, dots, NEG_INF)
    dots = dots - dots.max(axis=-1, keepdims=True)
    p = jnp.exp(dots)
    w = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    w = w * mhat[:, None]                                     # eq. (10)
    vt = jnp.einsum("nt,ntd->nd", w, vg)                      # eq. (16)
    o_ref[...] = (vt + vb).astype(o_ref.dtype)                # eq. (15)


def topk_refine(q, kg_q, vg_q, mhat_q, valid, v_b, *, block_n=128):
    """``V̂ = V̂^t + V̂^b`` given pre-gathered per-query top-k tiles."""
    n, dk = q.shape
    t = kg_q.shape[1]
    dv = vg_q.shape[-1]
    block_n = min(block_n, max(8, n))
    qp = _pad_to(q, block_n, 0)
    npad = qp.shape[0]

    kernel = functools.partial(_topk_refine_kernel, scale=1.0 / (dk ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dk), lambda i: (i, 0)),
            pl.BlockSpec((block_n, t, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, t, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, t), lambda i: (i, 0)),
            pl.BlockSpec((block_n, dv), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, dv), q.dtype),
        interpret=INTERPRET,
    )(
        qp,
        _pad_to(kg_q, block_n, 0),
        _pad_to(vg_q, block_n, 0),
        _pad_to(mhat_q, block_n, 0),
        _pad_to(valid.astype(q.dtype), block_n, 0),
        _pad_to(v_b, block_n, 0),
    )
    return out[:n]


# ---------------------------------------------------------------------------
# 5. Hamming K-Means assignment
# ---------------------------------------------------------------------------

def _hamming_assign_kernel(codes_ref, cent_ref, g_ref):
    """argmin Hamming distance == argmax ±1 dot product (MXU matmul)."""
    codes = codes_ref[...].astype(jnp.float32)                # (bn, B)
    cent = cent_ref[...].astype(jnp.float32)                  # (C, B)
    sim = codes @ cent.T                                      # (bn, C)
    g_ref[...] = jnp.argmax(sim, axis=-1).astype(jnp.int32)


def hamming_assign(codes, centroids, *, block_n=256):
    """One K-Means assignment step over ±1 codes."""
    n, bits = codes.shape
    c = centroids.shape[0]
    block_n = min(block_n, max(8, n))
    cp = _pad_to(codes, block_n, 0)

    out = pl.pallas_call(
        _hamming_assign_kernel,
        grid=(cp.shape[0] // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, bits), lambda i: (i, 0)),
            pl.BlockSpec((c, bits), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp.shape[0],), jnp.int32),
        interpret=INTERPRET,
    )(cp, centroids)
    return out[:n]


# ---------------------------------------------------------------------------
# high-level wrappers (drop-in for the ref.py API)
# ---------------------------------------------------------------------------

def clustered_attention_pallas(q, k, v, groups, n_clusters,
                               key_mask=None, point_mask=None):
    """Eqs. (3)–(6) with every hot loop inside a Pallas kernel."""
    sums, counts = centroid_sums(q, groups, n_clusters, point_mask)
    cent = sums / jnp.maximum(counts, 1.0)[:, None]
    _, v_c = centroid_attention(cent, k, v, key_mask)
    return v_c[groups]                                        # broadcast


def improved_clustered_attention_pallas(q, k, v, groups, n_clusters, topk,
                                        key_mask=None, point_mask=None):
    """Eqs. (9)–(11): Pallas for the dense work, XLA for sort/gather."""
    sums, counts = centroid_sums(q, groups, n_clusters, point_mask)
    cent = sums / jnp.maximum(counts, 1.0)[:, None]
    a_c, _ = centroid_attention(cent, k, v, key_mask)         # (C, N)

    # discrete selection: no gradient through which keys are picked
    _, top_idx = ref.sort_topk(lax.stop_gradient(a_c), topk)  # XLA sort
    t_mask = lax.stop_gradient(
        jax.nn.one_hot(top_idx, a_c.shape[-1], dtype=a_c.dtype).sum(1))
    mhat = (a_c * t_mask).sum(axis=-1)

    # V̂^b: zero the top-k columns, reuse the clustered path.
    v_b = ((a_c * (1.0 - t_mask)) @ v)[groups]

    # V̂^t: gather per-cluster tiles, refine in-kernel.
    kg_q = k[top_idx][groups]                                 # (N, t, Dk)
    vg_q = v[top_idx][groups]                                 # (N, t, Dv)
    if key_mask is not None:
        valid = key_mask.astype(bool)[top_idx][groups]
    else:
        valid = jnp.ones(kg_q.shape[:2], bool)
    return topk_refine(q, kg_q, vg_q, mhat[groups], valid, v_b)


def hamming_kmeans_pallas(codes, n_clusters, iters, point_mask=None):
    """Lloyd loop with the assignment step in the Pallas kernel.

    The update step (segment majority vote) reuses the centroid_sums
    kernel over ±1 codes.
    """
    cent = ref.init_centroid_codes(codes, n_clusters)
    for _ in range(iters):
        groups = hamming_assign(codes, cent)
        bit_sum, _ = centroid_sums(codes, groups, n_clusters, point_mask)
        cent = jnp.where(bit_sum > 0, 1.0,
                         jnp.where(bit_sum < 0, -1.0, cent)).astype(codes.dtype)
    return hamming_assign(codes, cent)
