"""Pure-jnp reference implementations (the correctness oracle).

Every attention variant of the paper exists here in its simplest correct
form.  The Pallas kernels (siblings in this package) are tested against
these functions, and the Rust reference implementation
(``rust/src/attention/``) is tested against HLO lowered from this module.

All functions operate on a *single head*: ``q, k`` are ``(N, Dk)``, ``v``
is ``(N, Dv)``.  Batch/head dimensions are added by ``model.py`` via
``jax.vmap``.

Notation follows the paper (NeurIPS 2020, Vyas et al.):
  - ``groups``  : ``S`` of eq. (3), as an int vector of cluster ids.
  - ``A^c``     : clustered attention matrix, eq. (4).
  - ``A^t``     : improved (top-k refined) attention matrix, eq. (10).

Compatibility note: ``lax.top_k`` lowers to an HLO ``topk`` op whose text
form the pinned xla_extension 0.5.1 parser rejects (``largest=true``), so
top-k is implemented with a two-operand ``lax.sort`` throughout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# small numerics helpers
# ---------------------------------------------------------------------------

def sort_topk(x: jnp.ndarray, k: int):
    """Descending top-k along the last axis via two-operand sort.

    Returns ``(values, indices)`` exactly like ``lax.top_k`` but lowers to
    an HLO ``sort`` the 0.5.1 text parser accepts.
    """
    iota = lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    neg, idx = lax.sort((-x, iota), dimension=-1, num_keys=1)
    return -neg[..., :k], idx[..., :k]


def masked_softmax(logits: jnp.ndarray, key_mask: jnp.ndarray | None):
    """Row softmax with optional key mask (1 = valid, 0 = padding)."""
    if key_mask is not None:
        logits = jnp.where(key_mask.astype(bool), logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# vanilla attention (the `full` baseline, §3.1)
# ---------------------------------------------------------------------------

def full_attention_matrix(q, k, key_mask=None):
    """``A = softmax(Q K^T / sqrt(Dk))`` — eq. (1)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    return masked_softmax(q @ k.T * scale, key_mask)


def full_attention(q, k, v, key_mask=None):
    """``V̂ = A V`` — eq. (2).  O(N^2 Dk + N^2 Dv)."""
    return full_attention_matrix(q, k, key_mask) @ v


def oracle_top_attention(q, k, v, topk: int, key_mask=None):
    """The paper's `oracle-top` baseline (§4.1).

    For every query keep only the ``topk`` keys with the highest exact
    attention and renormalise (softmax over just those keys).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = q @ k.T * scale
    if key_mask is not None:
        logits = jnp.where(key_mask.astype(bool), logits, NEG_INF)
    vals, idx = sort_topk(logits, topk)          # (N, topk)
    w = jax.nn.softmax(vals, axis=-1)
    vg = v[idx]                                  # (N, topk, Dv)
    return jnp.einsum("nk,nkd->nd", w, vg)


# ---------------------------------------------------------------------------
# LSH + Hamming-space K-Means (§3.2.2)
# ---------------------------------------------------------------------------

def lsh_codes(q, projections):
    """Sign-of-random-projection codes as ±1 floats.

    ``projections`` is ``(Dk, B)``.  ±1 (instead of packed bits) makes the
    Hamming distance an MXU-friendly dot product:
    ``hamming(a, b) = (B - a·b) / 2`` — see DESIGN.md §3.
    """
    return jnp.where(q @ projections >= 0, 1.0, -1.0).astype(q.dtype)


def init_centroid_codes(codes, n_clusters: int):
    """Deterministic strided init: every (N/C)-th code is a seed centroid."""
    n = codes.shape[0]
    idx = (jnp.arange(n_clusters) * n) // n_clusters
    return codes[idx]


def hamming_kmeans(codes, n_clusters: int, iters: int, point_mask=None):
    """Lloyd iterations in Hamming space over ±1 codes.

    Returns integer cluster ids ``(N,)``.  Assignment minimises the Hamming
    distance, i.e. maximises the dot product with the ±1 centroid.  The
    centroid update is the *sign of the member mean* (majority vote per
    bit), which is the Hamming-space centroid.  Empty clusters keep their
    previous centroid (``sign(0) -> previous``).
    """
    cent = init_centroid_codes(codes, n_clusters)
    if point_mask is not None:
        pm = point_mask.astype(codes.dtype)[:, None]   # (N, 1)
    else:
        pm = jnp.ones((codes.shape[0], 1), codes.dtype)

    def step(cent, _):
        # assignment: maximise dot == minimise hamming
        sim = codes @ cent.T                            # (N, C)
        groups = jnp.argmax(sim, axis=-1)
        one_hot = jax.nn.one_hot(groups, n_clusters, dtype=codes.dtype)
        one_hot = one_hot * pm                          # padding points vote 0
        bit_sum = one_hot.T @ codes                     # (C, B)
        new_cent = jnp.where(bit_sum > 0, 1.0,
                             jnp.where(bit_sum < 0, -1.0, cent))
        return new_cent.astype(codes.dtype), None

    cent, _ = lax.scan(step, cent, None, length=iters)
    groups = jnp.argmax(codes @ cent.T, axis=-1)
    return groups


def cluster_queries(q, n_clusters: int, bits: int, iters: int, key,
                    point_mask=None):
    """Full grouping pipeline of §3.2.2: LSH codes → Hamming K-Means.

    The assignment is not differentiable; gradients flow through the
    centroid *values* (means of member queries), so we stop the gradient
    on the ids only.
    """
    proj = jax.random.normal(key, (q.shape[-1], bits), dtype=q.dtype)
    codes = lsh_codes(lax.stop_gradient(q), proj)
    groups = hamming_kmeans(codes, n_clusters, iters, point_mask=point_mask)
    return lax.stop_gradient(groups)


# ---------------------------------------------------------------------------
# clustered attention (§3.2)
# ---------------------------------------------------------------------------

def cluster_centroids(q, groups, n_clusters: int, point_mask=None):
    """Eq. (3): per-cluster means of the member queries.

    Returns ``(centroids (C, Dk), counts (C,))``.  Padding queries (mask 0)
    contribute nothing.
    """
    one_hot = jax.nn.one_hot(groups, n_clusters, dtype=q.dtype)  # (N, C)
    if point_mask is not None:
        one_hot = one_hot * point_mask.astype(q.dtype)[:, None]
    counts = one_hot.sum(axis=0)                                 # (C,)
    sums = one_hot.T @ q                                         # (C, Dk)
    cent = sums / jnp.maximum(counts, 1.0)[:, None]
    return cent, counts


def clustered_attention_matrix(q, k, groups, n_clusters: int,
                               key_mask=None, point_mask=None):
    """``A^c`` of eq. (4) — (C, N)."""
    cent, _ = cluster_centroids(q, groups, n_clusters, point_mask)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    return masked_softmax(cent @ k.T * scale, key_mask)


def clustered_attention(q, k, v, groups, n_clusters: int,
                        key_mask=None, point_mask=None):
    """Eqs. (4)–(6): centroid attention + broadcast.  O(N·C·D)."""
    a_c = clustered_attention_matrix(q, k, groups, n_clusters,
                                     key_mask, point_mask)
    v_c = a_c @ v                                                # (C, Dv)
    return v_c[groups]                                           # broadcast


# ---------------------------------------------------------------------------
# improved clustered attention (§3.3)
# ---------------------------------------------------------------------------

def improved_clustered_attention(q, k, v, groups, n_clusters: int, topk: int,
                                 key_mask=None, point_mask=None):
    """Eqs. (9)–(11) via the decomposition of suppl. eqs. (15)–(17).

    ``V̂_i = V̂^t_i + V̂^b_i`` where the top-k part uses exact per-query dot
    products rescaled by the cluster's captured mass ``m̂_j`` and the bottom
    part is the clustered attention with the top-k columns zeroed.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    a_c = clustered_attention_matrix(q, k, groups, n_clusters,
                                     key_mask, point_mask)       # (C, N)

    # The *selection* of the top-k keys is discrete: gradients do not flow
    # through which keys are picked (also avoids differentiating lax.sort,
    # whose transpose rule needs batched gathers this XLA lacks).  The
    # captured mass m̂ (eq. 9) is recovered differentiably via the mask.
    _, top_idx = sort_topk(lax.stop_gradient(a_c), topk)         # (C, topk)
    t_mask = lax.stop_gradient(
        jax.nn.one_hot(top_idx, a_c.shape[-1], dtype=a_c.dtype).sum(1))
    mhat = (a_c * t_mask).sum(axis=-1)                           # (C,) eq. (9)

    # --- V̂^t: exact dots on the top-k keys of the query's cluster ---------
    kg = k[top_idx]                                              # (C, topk, Dk)
    vg = v[top_idx]                                              # (C, topk, Dv)
    kg_q = kg[groups]                                            # (N, topk, Dk)
    vg_q = vg[groups]                                            # (N, topk, Dv)
    dots = jnp.einsum("nd,nkd->nk", q, kg_q) * scale             # (N, topk)
    if key_mask is not None:
        valid = key_mask.astype(bool)[top_idx][groups]           # (N, topk)
        dots = jnp.where(valid, dots, NEG_INF)
    w = jax.nn.softmax(dots, axis=-1) * mhat[groups][:, None]    # eq. (10)
    v_t = jnp.einsum("nk,nkd->nd", w, vg_q)                      # eq. (16)

    # --- V̂^b: clustered attention on the complement -----------------------
    a_b = a_c * (1.0 - t_mask)
    v_b = (a_b @ v)[groups]                                      # eq. (17)
    return v_t + v_b


def improved_clustered_attention_matrix(q, k, groups, n_clusters: int,
                                        topk: int, key_mask=None,
                                        point_mask=None):
    """Dense ``A^t`` of eq. (10) — (N, N).  For analysis/fig. 8 only."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    a_c = clustered_attention_matrix(q, k, groups, n_clusters,
                                     key_mask, point_mask)
    top_vals, top_idx = sort_topk(a_c, topk)
    mhat = top_vals.sum(axis=-1)
    t_mask = jnp.zeros_like(a_c).at[
        jnp.arange(a_c.shape[0])[:, None], top_idx].set(1.0)     # (C, N)

    logits = q @ k.T * scale                                     # (N, N)
    if key_mask is not None:
        logits = jnp.where(key_mask.astype(bool), logits, NEG_INF)
    tq = t_mask[groups]                                          # (N, N)
    exp = jnp.exp(logits - logits.max(axis=-1, keepdims=True)) * tq
    denom = jnp.maximum(exp.sum(axis=-1, keepdims=True), 1e-30)
    a_top = exp / denom * mhat[groups][:, None]
    return jnp.where(tq > 0, a_top, a_c[groups])


# ---------------------------------------------------------------------------
# Reformer-style LSH attention (the `lsh-X` baseline, §2.3 / [13])
# ---------------------------------------------------------------------------

def _lsh_buckets(x, n_buckets: int, key):
    """Angular LSH of the Reformer: argmax over [xR; -xR] rotations."""
    rot = jax.random.normal(key, (x.shape[-1], n_buckets // 2), dtype=x.dtype)
    h = x @ rot
    return jnp.argmax(jnp.concatenate([h, -h], axis=-1), axis=-1)


def reformer_attention(x, v, rounds: int, chunk: int, key,
                       key_mask=None, n_buckets: int = 16):
    """Shared-QK chunked LSH attention, averaged over hashing rounds.

    Faithful to Kitaev et al. at the level the paper benchmarks it:
      - queries == keys (shared projection), self-attention penalises self
        so it is used only as a fallback;
      - positions are sorted by bucket, attention runs within each chunk
        and its predecessor, masked to same-bucket pairs;
      - rounds are combined with logsumexp weights.

    O(rounds · N · (2·chunk) · D).
    """
    n, d = x.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, x.dtype))
    n_chunks = n // chunk
    assert n % chunk == 0, "sequence length must be divisible by chunk"

    def one_round(rkey):
        buckets = _lsh_buckets(x, n_buckets, rkey)               # (N,)
        if key_mask is not None:
            # push padding to the very end of the sort order
            buckets = jnp.where(key_mask.astype(bool), buckets, n_buckets + 1)
        # stable sort positions by bucket
        order = lax.sort((buckets.astype(jnp.int32),
                          jnp.arange(n, dtype=jnp.int32)),
                         dimension=0, num_keys=1)[1]              # (N,)
        xs = x[order]                                            # sorted qk
        vs = v[order]
        bs = buckets[order]

        xs_c = xs.reshape(n_chunks, chunk, d)
        vs_c = vs.reshape(n_chunks, chunk, -1)
        bs_c = bs.reshape(n_chunks, chunk)
        # each chunk attends to [previous chunk, itself]
        prev = lambda a: jnp.roll(a, 1, axis=0)
        kk = jnp.concatenate([prev(xs_c), xs_c], axis=1)          # (nc, 2c, d)
        vv = jnp.concatenate([prev(vs_c), vs_c], axis=1)
        bb = jnp.concatenate([prev(bs_c), bs_c], axis=1)          # (nc, 2c)

        logits = jnp.einsum("cqd,ckd->cqk", xs_c, kk) * scale
        same_bucket = bs_c[:, :, None] == bb[:, None, :]
        logits = jnp.where(same_bucket, logits, NEG_INF)
        # penalise self-attention (used only when nothing else matches)
        qpos = order.reshape(n_chunks, chunk)
        kpos = jnp.concatenate([prev(qpos), qpos], axis=1)
        is_self = qpos[:, :, None] == kpos[:, None, :]
        logits = jnp.where(is_self, NEG_INF / 2, logits)

        lse = jax.nn.logsumexp(logits, axis=-1)                   # (nc, c)
        out_s = jnp.einsum("cqk,ckd->cqd", jax.nn.softmax(logits, -1), vv)
        # unsort
        inv = jnp.zeros(n, jnp.int32).at[order].set(
            jnp.arange(n, dtype=jnp.int32))
        out = out_s.reshape(n, -1)[inv]
        return out, lse.reshape(n)[inv]

    keys = jax.random.split(key, rounds)
    outs, lses = jax.vmap(one_round)(keys)                        # (R, N, Dv)
    w = jax.nn.softmax(lses, axis=0)                              # (R, N)
    return (outs * w[:, :, None]).sum(axis=0)
