"""Task losses: CTC (forward algorithm), cross-entropy, span loss.

CTC is implemented from scratch (Graves et al. 2006) in log space with a
``lax.scan`` over time so the whole train step lowers into one HLO module.
Blank id is 0; labels are 1-based.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

LOG_EPS = -1e9


def log_softmax(x, axis=-1):
    return x - jax.nn.logsumexp(x, axis=axis, keepdims=True)


def ctc_loss_single(logits, input_len, labels, label_len):
    """Negative log likelihood of ``labels`` under CTC.

    logits     : (T, V) raw scores, blank = class 0
    input_len  : () int32, number of valid frames (<= T)
    labels     : (L,) int32 padded label sequence (values in 1..V-1)
    label_len  : () int32, number of valid labels (<= L)
    """
    t_max, _ = logits.shape
    l_max = labels.shape[0]
    u = 2 * l_max + 1
    logp = log_softmax(logits)

    # extended label sequence: blank, l1, blank, l2, ..., blank
    ext = jnp.zeros((u,), jnp.int32)
    ext = ext.at[1::2].set(labels)
    # skip transition allowed when z[u] != blank and z[u] != z[u-2]
    ext_prev2 = jnp.concatenate([jnp.full((2,), -1, jnp.int32), ext[:-2]])
    can_skip = (ext != 0) & (ext != ext_prev2)

    pos = jnp.arange(u)
    valid_u = pos < (2 * label_len + 1)

    alpha0 = jnp.full((u,), LOG_EPS)
    alpha0 = alpha0.at[0].set(logp[0, 0])
    alpha0 = alpha0.at[1].set(jnp.where(label_len > 0, logp[0, ext[1]],
                                        LOG_EPS))

    def shift1(a):
        return jnp.concatenate([jnp.array([LOG_EPS]), a[:-1]])

    def shift2(a):
        return jnp.concatenate([jnp.array([LOG_EPS, LOG_EPS]), a[:-2]])

    def step(alpha, t):
        stay = alpha
        diag = shift1(alpha)
        skip = jnp.where(can_skip, shift2(alpha), LOG_EPS)
        merged = jnp.logaddexp(jnp.logaddexp(stay, diag), skip)
        new = merged + logp[t, ext]
        new = jnp.where(valid_u, new, LOG_EPS)
        # frames beyond input_len leave alpha untouched
        new = jnp.where(t < input_len, new, alpha)
        return new, None

    alpha, _ = lax.scan(step, alpha0, jnp.arange(1, t_max))
    end = 2 * label_len           # final blank
    end_prev = jnp.maximum(end - 1, 0)  # final label
    ll = jnp.logaddexp(alpha[end], alpha[end_prev])
    return -ll


def ctc_loss(logits, input_lens, labels, label_lens):
    """Batched mean CTC loss, normalised by label length (Kaldi-style)."""
    per = jax.vmap(ctc_loss_single)(logits, input_lens, labels, label_lens)
    return (per / jnp.maximum(label_lens.astype(jnp.float32), 1.0)).mean()


def token_ce_loss(logits, targets, weight_mask):
    """Per-position CE averaged over weighted positions (copy task)."""
    lp = log_softmax(logits)
    ll = jnp.take_along_axis(lp, targets[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    w = weight_mask.astype(jnp.float32)
    return -(ll * w).sum() / jnp.maximum(w.sum(), 1.0)


def cls_ce_loss(logits, targets):
    """Sequence classification CE (GLUE-analog tasks)."""
    lp = log_softmax(logits)
    ll = jnp.take_along_axis(lp, targets[:, None].astype(jnp.int32),
                             axis=-1)[:, 0]
    return -ll.mean()


def span_loss(logits, starts, ends, key_mask):
    """SQuAD-analog: CE over start positions + CE over end positions.

    logits: (B, N, 2); invalid positions are masked out of the softmax.
    """
    masked = jnp.where(key_mask[..., None] > 0, logits, LOG_EPS)
    ls = log_softmax(masked[..., 0], axis=-1)
    le = log_softmax(masked[..., 1], axis=-1)
    pick = lambda lp, idx: jnp.take_along_axis(
        lp, idx[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return -(pick(ls, starts) + pick(le, ends)).mean() / 2.0
