"""The experiment registry: every HLO program the benches need.

This is the single source of truth for experiment configurations, shared
between ``aot.py`` (which lowers programs) and the Rust benches (which
read the emitted ``manifest.json``).  Scales are shrunk from the paper's
GPU testbed to CPU-feasible sizes while preserving the N ≫ C regime —
see DESIGN.md §7 for the mapping.
"""

from __future__ import annotations

from .configs import AttentionConfig, ModelConfig, attn_variant_name

A = AttentionConfig


# ---------------------------------------------------------------------------
# base architectures
# ---------------------------------------------------------------------------

def copy_cfg(n: int, attn: AttentionConfig, layers: int = 4) -> ModelConfig:
    """Masked copy task (§C.2): input 0w0w with masked symbols.

    vocab_in: 0 = separator, 1..10 symbols, 11 = MASK.  Outputs 0..10.
    """
    return ModelConfig(
        name=f"copy-n{n}-{attn_variant_name(attn)}", task="tok",
        attention=attn, n_layers=layers, n_heads=4, d_head=16, d_ff=128,
        n_symbols=11, vocab_in=12, seq_len=n, batch_size=16, lr=2e-3)


def wsj_cfg(attn: AttentionConfig, layers: int) -> ModelConfig:
    """WSJ-analog synthetic ASR: 40-d filterbank-like frames, phoneme CTC.

    Paper: N̄=780, 9 layers, C∈{100..300}; here N=256, ≤6 layers,
    C∈{25..75} (same N/C ratios).
    """
    return ModelConfig(
        name=f"wsj-l{layers}-{attn_variant_name(attn)}", task="ctc",
        attention=attn, n_layers=layers, n_heads=6, d_head=16, d_ff=192,
        n_symbols=20, d_in=40, seq_len=256, batch_size=4, max_labels=48,
        lr=5e-4)


def swb_cfg(attn: AttentionConfig, layers: int) -> ModelConfig:
    """Switchboard-analog: longer/noisier synthetic ASR (paper: N̄=534,
    max 3850, 12 layers).  CTC replaces LF-MMI (DESIGN.md §2)."""
    return ModelConfig(
        name=f"swb-l{layers}-{attn_variant_name(attn)}", task="ctc",
        attention=attn, n_layers=layers, n_heads=6, d_head=16, d_ff=192,
        n_symbols=40, d_in=40, seq_len=384, batch_size=2, max_labels=64,
        lr=5e-4)


GLUE_TASKS = {
    # name -> (task head, n classes) — synthetic analogs, DESIGN.md §2
    "sst2": ("cls", 2),    # majority sentiment of ± tokens
    "mrpc": ("cls", 2),    # are the two halves permutations of each other
    "qnli": ("cls", 2),    # does the context contain the query pattern
    "rte": ("cls", 2),     # second-half vocabulary ⊆ first-half vocabulary
    "squad": ("span", 2),  # find the answer span for the question pattern
}


def glue_cfg(task_name: str, attn: AttentionConfig) -> ModelConfig:
    head, ncls = GLUE_TASKS[task_name]
    n = 192 if task_name == "squad" else 128
    return ModelConfig(
        name=f"glue-{task_name}-{attn_variant_name(attn)}", task=head,
        attention=attn, n_layers=4, n_heads=4, d_head=16, d_ff=128,
        n_symbols=ncls, vocab_in=32, seq_len=n, batch_size=8, lr=1e-3)


def layer_cfg(n: int, attn: AttentionConfig) -> ModelConfig:
    """Single attention layer for the fig. 4 scaling microbench."""
    return ModelConfig(
        name=f"layer-n{n}-{attn_variant_name(attn)}", task="tok",
        attention=attn, n_layers=1, n_heads=6, d_head=16, d_ff=96,
        n_symbols=8, vocab_in=16, seq_len=n, batch_size=1)


# ---------------------------------------------------------------------------
# attention variant palettes
# ---------------------------------------------------------------------------

def clustered(c, pallas=False):
    return A(kind="clustered", clusters=c, bits=31, lloyd_iters=10,
             use_pallas=pallas)


def iclustered(c, topk=16, pallas=False):
    return A(kind="i-clustered", clusters=c, topk=topk, bits=31,
             lloyd_iters=10, use_pallas=pallas)


def lsh(rounds, chunk=16):
    return A(kind="lsh", rounds=rounds, chunk=chunk)


FULL = A(kind="full")
SHARED = A(kind="shared-full")
ORACLE = A(kind="oracle-top", topk=16)


# ---------------------------------------------------------------------------
# program sets  (name -> (kind, ModelConfig[, extra]))
# ---------------------------------------------------------------------------

def build_registry():
    """Returns {program_name: (program_kind, cfg, extra_dict)}."""
    progs = {}

    def add(kind, cfg, extra=None):
        name = f"{cfg.name}.{kind}"
        progs[name] = (kind, cfg, extra or {})

    def add_model(cfg, train=True, fwd=True):
        if train:
            add("init", cfg)
            add("train", cfg)
        if fwd:
            add("forward", cfg)

    # --- fig5 / copy-task heatmap -------------------------------------
    copy_variants = ([FULL] + [clustered(c) for c in (8, 15, 30)]
                     + [iclustered(c, topk=8) for c in (8, 15, 30)]
                     + [lsh(r) for r in (1, 4, 8)])
    for n in (32, 64, 128):
        for attn in copy_variants:
            add_model(copy_cfg(n, attn))

    # pallas-twin of the copy forward (kernel path composes end-to-end)
    add("forward", copy_cfg(64, iclustered(8, topk=8, pallas=True)))
    add("forward", copy_cfg(64, clustered(8, pallas=True)))

    # --- WSJ-analog: fig1a + table1 + table2 --------------------------
    for layers in (2, 4, 6):
        add_model(wsj_cfg(FULL, layers))
    add_model(wsj_cfg(SHARED, 6))
    for c in (25, 50, 75):
        add_model(wsj_cfg(clustered(c), 6))
    for c in (25, 50):
        add_model(wsj_cfg(iclustered(c), 6))
        add_model(wsj_cfg(iclustered(c), 4))
    for r in (1, 4):
        add_model(wsj_cfg(lsh(r, chunk=32), 6))
    # eval-only variants for the table-1 cross matrix (checkpoint reuse)
    add("forward", wsj_cfg(ORACLE, 6))

    # --- SWB-analog: fig1b + table3 ------------------------------------
    for layers in (2, 4, 6):
        add_model(swb_cfg(FULL, layers) if layers == 6
                  else swb_cfg(FULL, layers), train=True, fwd=True)
    add_model(swb_cfg(clustered(25), 6))
    add_model(swb_cfg(iclustered(25), 6))
    add_model(swb_cfg(iclustered(50), 6))

    # --- GLUE/SQuAD-analog: table4 + fig8 ------------------------------
    for t in GLUE_TASKS:
        add_model(glue_cfg(t, FULL))                      # pretrain full
        add("forward", glue_cfg(t, clustered(25)))        # approx eval
        add("forward", glue_cfg(t, iclustered(25, topk=16)))
    add("attention_maps", glue_cfg("squad", iclustered(25, topk=16)),
        {"layer": 3, "head": 0})

    # --- cross-implementation golden check (Rust vs jnp oracle) --------
    progs["attncheck-n64.check"] = (
        "attn_check",
        copy_cfg(64, FULL),  # carrier config (shapes come from extra)
        {"n": 64, "dk": 16, "dv": 16, "clusters": 8, "topk": 8})

    # --- fig4 scaling (single layer, forward only) ---------------------
    for n in (256, 512, 1024):
        for attn in (FULL, clustered(25), iclustered(25), lsh(1, chunk=32),
                     lsh(4, chunk=32)):
            add("forward", layer_cfg(n, attn))

    return progs
