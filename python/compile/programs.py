"""AOT program definitions: init / train_step / forward / attention_maps.

Each builder returns ``(fn, arg_specs, arg_names, out_names)`` where
``arg_specs`` are ``jax.ShapeDtypeStruct``s.  ``aot.py`` lowers these to
HLO text; the names/shapes go into ``manifest.json`` so the Rust runtime
can construct inputs without ever importing Python.

Batch layouts per task
  tok  : x (B,N) i32 tokens, y (B,N) i32 targets, w (B,N) f32 loss weights
  ctc  : x (B,N,Din) f32, xlen (B,) i32, y (B,Lmax) i32, ylen (B,) i32
  cls  : x (B,N) i32, mask (B,N) f32, y (B,) i32
  span : x (B,N) i32, mask (B,N) f32, ystart (B,) i32, yend (B,) i32
Every program also takes ``seed`` (i32 scalar) feeding the in-graph
randomness (LSH projections, Reformer rotations).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import losses, model, optim
from .configs import ModelConfig

f32, i32 = jnp.float32, jnp.int32


def _spec(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig):
    """(specs, names) of the task's batch tensors, in canonical order."""
    b, n = cfg.batch_size, cfg.seq_len
    if cfg.task == "tok":
        return ([_spec((b, n), i32), _spec((b, n), i32), _spec((b, n), f32)],
                ["x", "y", "w"])
    if cfg.task == "ctc":
        return ([_spec((b, n, cfg.d_in), f32), _spec((b,), i32),
                 _spec((b, cfg.max_labels), i32), _spec((b,), i32)],
                ["x", "xlen", "y", "ylen"])
    if cfg.task == "cls":
        return ([_spec((b, n), i32), _spec((b, n), f32), _spec((b,), i32)],
                ["x", "mask", "y"])
    if cfg.task == "span":
        return ([_spec((b, n), i32), _spec((b, n), f32),
                 _spec((b,), i32), _spec((b,), i32)],
                ["x", "mask", "ystart", "yend"])
    raise ValueError(cfg.task)


def _key_mask(cfg: ModelConfig, batch):
    n = cfg.seq_len
    if cfg.task == "tok":
        return jnp.ones(batch[0].shape, f32)
    if cfg.task == "ctc":
        xlen = batch[1]
        return (jnp.arange(n)[None, :] < xlen[:, None]).astype(f32)
    return batch[1]  # cls / span carry an explicit mask


def batch_loss(cfg: ModelConfig, params, batch, seed):
    mask = _key_mask(cfg, batch)
    if cfg.task == "tok":
        x, y, w = batch
        logits = model.forward(cfg, params, x, mask, seed)
        return losses.token_ce_loss(logits, y, w)
    if cfg.task == "ctc":
        x, xlen, y, ylen = batch
        logits = model.forward(cfg, params, x, mask, seed)
        return losses.ctc_loss(logits, xlen, y, ylen)
    if cfg.task == "cls":
        x, _, y = batch
        logits = model.forward(cfg, params, x, mask, seed)
        return losses.cls_ce_loss(logits, y)
    if cfg.task == "span":
        x, _, ys, ye = batch
        logits = model.forward(cfg, params, x, mask, seed)
        return losses.span_loss(logits, ys, ye, mask)
    raise ValueError(cfg.task)


# ---------------------------------------------------------------------------
# program builders
# ---------------------------------------------------------------------------

def make_init(cfg: ModelConfig):
    def fn(seed):
        p = model.init_params(cfg, seed)
        z = jnp.zeros_like(p)
        return p, z, z, jnp.zeros((), i32)

    return (fn, [_spec((), i32)], ["seed"],
            ["params", "adam_m", "adam_v", "step"])


def make_train_step(cfg: ModelConfig):
    npar = model.param_count(cfg)
    bspecs, bnames = batch_specs(cfg)

    def fn(params, m, v, step, seed, *batch):
        loss, grads = jax.value_and_grad(
            lambda p: batch_loss(cfg, p, batch, seed))(params)
        params, m, v, step = optim.adam_step(
            params, m, v, step, grads, lr=cfg.lr,
            weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip)
        return params, m, v, step, loss

    specs = [_spec((npar,)), _spec((npar,)), _spec((npar,)),
             _spec((), i32), _spec((), i32)] + bspecs
    names = ["params", "adam_m", "adam_v", "step", "seed"] + bnames
    return fn, specs, names, ["params", "adam_m", "adam_v", "step", "loss"]


def make_forward(cfg: ModelConfig):
    """Inference program: logits (+ loss-independent).  The serving path."""
    npar = model.param_count(cfg)
    b, n = cfg.batch_size, cfg.seq_len

    if cfg.task == "ctc":
        xspec = [_spec((b, n, cfg.d_in), f32), _spec((b,), i32)]
        xnames = ["x", "xlen"]

        def fn(params, x, xlen, seed):
            mask = (jnp.arange(n)[None, :] < xlen[:, None]).astype(f32)
            return (model.forward(cfg, params, x, mask, seed),)
    elif cfg.task == "tok":
        xspec = [_spec((b, n), i32)]
        xnames = ["x"]

        def fn(params, x, seed):
            return (model.forward(cfg, params, x, jnp.ones((b, n), f32),
                                  seed),)
    else:  # cls / span
        xspec = [_spec((b, n), i32), _spec((b, n), f32)]
        xnames = ["x", "mask"]

        def fn(params, x, mask, seed):
            return (model.forward(cfg, params, x, mask, seed),)

    specs = [_spec((npar,))] + xspec + [_spec((), i32)]
    names = ["params"] + xnames + ["seed"]
    return fn, specs, names, ["logits"]


def make_eval_loss(cfg: ModelConfig):
    """Validation loss program (no gradient) — convergence tracking."""
    npar = model.param_count(cfg)
    bspecs, bnames = batch_specs(cfg)

    def fn(params, seed, *batch):
        return (batch_loss(cfg, params, batch, seed),)

    specs = [_spec((npar,)), _spec((), i32)] + bspecs
    names = ["params", "seed"] + bnames
    return fn, specs, names, ["loss"]


def make_attn_check(n: int, dk: int, dv: int, clusters: int, topk: int):
    """Cross-implementation golden check: given identical (q, k, v, groups),
    emit full / clustered / i-clustered outputs from the jnp oracle.  The
    Rust integration test feeds the same tensors to its native
    implementation and asserts allclose — proving the three codebases
    (jnp, Pallas, Rust) agree."""
    from .kernels import ref

    def fn(q, k, v, groups):
        return (
            ref.full_attention(q, k, v),
            ref.clustered_attention(q, k, v, groups, clusters),
            ref.improved_clustered_attention(q, k, v, groups, clusters,
                                             topk),
        )

    specs = [_spec((n, dk)), _spec((n, dk)), _spec((n, dv)),
             _spec((n,), i32)]
    return fn, specs, ["q", "k", "v", "groups"], \
        ["full", "clustered", "improved"]


def make_attention_maps(cfg: ModelConfig, layer: int, head: int):
    """Fig. 8 program: A (full), A^c broadcast, A^t for one sample."""
    npar = model.param_count(cfg)
    n = cfg.seq_len

    def fn(params, x, mask, seed):
        return model.attention_maps(cfg, params, x, mask, seed, layer, head)

    specs = [_spec((npar,)), _spec((n,), i32), _spec((n,), f32),
             _spec((), i32)]
    return fn, specs, ["params", "x", "mask", "seed"], \
        ["a_full", "a_clustered", "a_improved"]
