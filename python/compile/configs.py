"""Model / attention configuration dataclasses shared by L2 and aot.py.

A config fully determines HLO artifact shapes, the flat parameter layout
and the training hyper-parameters, and is serialised into
``artifacts/manifest.json`` so the Rust coordinator can reason about it.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class AttentionConfig:
    """Which attention variant a model uses (paper §3 + baselines §4).

    kind:
      - ``full``        vanilla softmax attention (eq. 1–2)
      - ``shared-full`` vanilla with Q == K (Reformer-comparable baseline)
      - ``clustered``   §3.2  (LSH → Hamming K-Means → centroid attention)
      - ``i-clustered`` §3.3  (clustered + exact top-k refinement)
      - ``lsh``         Reformer-style chunked LSH attention
      - ``oracle-top``  exact per-query top-k (upper-bound baseline, §4.1)
    """
    kind: str = "full"
    clusters: int = 100       # C
    topk: int = 32            # k  (i-clustered / oracle-top)
    bits: int = 31            # B  LSH bits (paper: 63)
    lloyd_iters: int = 10     # L  K-Means iterations (paper: 10)
    rounds: int = 1           # X  Reformer hashing rounds
    chunk: int = 32           # Reformer chunk size (paper: 32)
    use_pallas: bool = False  # route hot loops through the Pallas kernels


@dataclass(frozen=True)
class ModelConfig:
    """A transformer encoder + task head."""
    name: str = "model"
    task: str = "tok"         # tok | ctc | cls | span
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 16
    d_ff: int = 128
    n_symbols: int = 16       # output vocabulary (CTC adds blank internally)
    vocab_in: int = 0         # input token vocab (>0 → embedding input)
    d_in: int = 0             # input feature dim (>0 → linear input)
    seq_len: int = 128        # N (static)
    batch_size: int = 16      # B (static)
    max_labels: int = 32      # CTC label budget per sample
    lr: float = 2e-4          # R-Adam-ish Adam step size (paper: 2e-4)
    weight_decay: float = 0.01
    grad_clip: float = 10.0   # paper: max grad norm 10

    @property
    def d_model(self) -> int:
        return self.n_heads * self.d_head

    @property
    def out_dim(self) -> int:
        if self.task == "ctc":
            return self.n_symbols + 1      # + blank (id 0)
        if self.task == "span":
            return 2                        # start / end logits
        return self.n_symbols

    def to_json_dict(self) -> dict:
        d = asdict(self)
        d["d_model"] = self.d_model
        d["out_dim"] = self.out_dim
        return d


def attn_variant_name(a: AttentionConfig) -> str:
    """Short name matching the paper's notation (clustered-100, lsh-4, ...).

    A ``-pallas`` suffix marks the L1-kernel build of a variant so its
    artifacts never collide with the jnp-ref build of the same config.
    """
    suffix = "-pallas" if a.use_pallas else ""
    if a.kind in ("full", "shared-full"):
        return a.kind + suffix
    if a.kind == "clustered":
        return f"clustered-{a.clusters}{suffix}"
    if a.kind == "i-clustered":
        return f"i-clustered-{a.clusters}{suffix}"
    if a.kind == "lsh":
        return f"lsh-{a.rounds}"
    if a.kind == "oracle-top":
        return f"oracle-top-{a.topk}"
    raise ValueError(a.kind)
