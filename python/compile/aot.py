"""AOT lowering: registry programs → ``artifacts/*.hlo.txt`` + manifest.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  Lowered with ``return_tuple=True``; the Rust side unwraps with
``to_tupleN``.

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [-j N]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import multiprocessing as mp
import os
import re
import sys
import time

import jax

from . import model, programs
from .configs import ModelConfig
from .registry import build_registry


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def build_program(kind: str, cfg: ModelConfig, extra: dict):
    if kind == "init":
        return programs.make_init(cfg)
    if kind == "train":
        return programs.make_train_step(cfg)
    if kind == "forward":
        return programs.make_forward(cfg)
    if kind == "eval_loss":
        return programs.make_eval_loss(cfg)
    if kind == "attention_maps":
        return programs.make_attention_maps(cfg, extra["layer"],
                                            extra["head"])
    if kind == "attn_check":
        return programs.make_attn_check(extra["n"], extra["dk"],
                                        extra["dv"], extra["clusters"],
                                        extra["topk"])
    raise ValueError(kind)


def lower_one(job):
    """Worker: lower one registry entry to HLO text.  Returns manifest row."""
    name, kind, cfg, extra, out_dir = job
    t0 = time.time()
    fn, specs, in_names, out_names = build_program(kind, cfg, extra)
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = name.replace("/", "_") + ".hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "kind": kind,
        "file": fname,
        "inputs": [{"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                   for n, s in zip(in_names, specs)],
        "outputs": out_names,
        "config": cfg.to_json_dict(),
        "param_count": model.param_count(cfg),
        "hlo_bytes": len(text),
        "lower_seconds": round(time.time() - t0, 2),
    }
    sys.stderr.write(f"  lowered {name} ({len(text)//1024} KiB, "
                     f"{entry['lower_seconds']}s)\n")
    return entry


def source_fingerprint() -> str:
    """Hash of the compile package — lets `make artifacts` skip cleanly."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                h.update(open(os.path.join(root, f), "rb").read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on program names")
    ap.add_argument("-j", type=int, default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    fp = source_fingerprint()

    if not args.force and not args.only and os.path.exists(manifest_path):
        try:
            old = json.load(open(manifest_path))
            if old.get("fingerprint") == fp:
                print("artifacts up to date (fingerprint match)")
                return
        except Exception:
            pass

    reg = build_registry()
    jobs = []
    for name, (kind, cfg, extra) in sorted(reg.items()):
        if args.only and not re.search(args.only, name):
            continue
        jobs.append((name, kind, cfg, extra, args.out_dir))

    print(f"lowering {len(jobs)} programs with {args.j} workers ...")
    t0 = time.time()
    if args.j > 1:
        with mp.get_context("spawn").Pool(args.j) as pool:
            entries = pool.map(lower_one, jobs)
    else:
        entries = [lower_one(j) for j in jobs]

    # merge with existing manifest when --only is used
    merged = {}
    if args.only and os.path.exists(manifest_path):
        try:
            for e in json.load(open(manifest_path))["programs"]:
                merged[e["name"]] = e
        except Exception:
            pass
    for e in entries:
        merged[e["name"]] = e

    manifest = {
        "fingerprint": fp if not args.only else "partial",
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "programs": sorted(merged.values(), key=lambda e: e["name"]),
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(merged)} programs to {manifest_path} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
