"""Layer-2: the transformer encoder with pluggable attention.

Parameters live in ONE flat f32 vector; :func:`param_spec` defines the
canonical layout (also exported to ``manifest.json`` so the Rust runtime
can checkpoint/inspect).  All attention variants share the same layout,
which is what makes the paper's §4 "train with X, evaluate with Y"
experiments (Table 1, Table 4) a pure artifact swap on the Rust side.

The per-head attention math is delegated to ``kernels.ref`` (oracle) or
``kernels.pallas_kernels`` (L1 kernels) depending on
``AttentionConfig.use_pallas``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .configs import AttentionConfig, ModelConfig
from .kernels import ref
from .kernels import pallas_kernels as pk


# ---------------------------------------------------------------------------
# flat parameter layout
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Canonical (name, shape) list; flat offsets follow this order."""
    d = cfg.d_model
    spec = []
    if cfg.vocab_in > 0:
        spec.append(("embed", (cfg.vocab_in, d)))
    else:
        spec.append(("in_proj/w", (cfg.d_in, d)))
        spec.append(("in_proj/b", (d,)))
    # learned positional embeddings, initialised to the sinusoidal table
    # (static N per artifact, so a table is exact; learnable because the
    # copy/span tasks need sharp position-matching heads)
    spec.append(("pos_embed", (cfg.seq_len, d)))
    for i in range(cfg.n_layers):
        p = f"layer{i}/"
        spec += [
            (p + "ln1/g", (d,)), (p + "ln1/b", (d,)),
            (p + "attn/wq", (d, d)), (p + "attn/wk", (d, d)),
            (p + "attn/wv", (d, d)), (p + "attn/wo", (d, d)),
            (p + "attn/bo", (d,)),
            (p + "ln2/g", (d,)), (p + "ln2/b", (d,)),
            (p + "ff1/w", (d, cfg.d_ff)), (p + "ff1/b", (cfg.d_ff,)),
            (p + "ff2/w", (cfg.d_ff, d)), (p + "ff2/b", (d,)),
        ]
    spec += [
        ("ln_f/g", (d,)), ("ln_f/b", (d,)),
        ("head/w", (d, cfg.out_dim)), ("head/b", (cfg.out_dim,)),
    ]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return sum(int(math.prod(s)) for _, s in param_spec(cfg))


def unpack_params(cfg: ModelConfig, flat: jnp.ndarray) -> dict:
    """Static-offset slicing of the flat vector into named arrays."""
    out, off = {}, 0
    for name, shape in param_spec(cfg):
        size = int(math.prod(shape))
        out[name] = lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
        off += size
    return out


def init_params(cfg: ModelConfig, seed) -> jnp.ndarray:
    """Deterministic init of the flat vector (traced-seed friendly)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for idx, (name, shape) in enumerate(param_spec(cfg)):
        k = jax.random.fold_in(key, idx)
        size = int(math.prod(shape))
        if name == "pos_embed":
            pe = sinusoidal_pe(shape[0], shape[1])
            chunks.append(pe.reshape(-1))
        elif name.endswith("/b") or name.endswith("/bo"):
            chunks.append(jnp.zeros((size,), jnp.float32))
        elif "ln" in name and name.endswith("/g"):
            chunks.append(jnp.ones((size,), jnp.float32))
        elif "ln" in name:
            chunks.append(jnp.zeros((size,), jnp.float32))
        else:
            fan_in = shape[0] if len(shape) == 2 else size
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            chunks.append(scale * jax.random.normal(k, (size,), jnp.float32))
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * g + b


def sinusoidal_pe(n, d, dtype=jnp.float32):
    pos = jnp.arange(n, dtype=dtype)[:, None]
    i = jnp.arange(d // 2, dtype=dtype)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * i / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d]


def head_attention(a: AttentionConfig, q, k, v, key_mask, rng):
    """Dispatch a single head's (N, dh) attention to the right variant."""
    if a.kind == "full" or a.kind == "shared-full":
        fn = pk.flash_attention if a.use_pallas else ref.full_attention
        return fn(q, k, v, key_mask)
    if a.kind == "clustered":
        groups = ref.cluster_queries(q, a.clusters, a.bits, a.lloyd_iters,
                                     rng, point_mask=key_mask)
        fn = (pk.clustered_attention_pallas if a.use_pallas
              else ref.clustered_attention)
        return fn(q, k, v, groups, a.clusters,
                  key_mask=key_mask, point_mask=key_mask)
    if a.kind == "i-clustered":
        groups = ref.cluster_queries(q, a.clusters, a.bits, a.lloyd_iters,
                                     rng, point_mask=key_mask)
        fn = (pk.improved_clustered_attention_pallas if a.use_pallas
              else ref.improved_clustered_attention)
        return fn(q, k, v, groups, a.clusters, a.topk,
                  key_mask=key_mask, point_mask=key_mask)
    if a.kind == "lsh":
        return ref.reformer_attention(q, v, a.rounds, a.chunk, rng,
                                      key_mask=key_mask)
    if a.kind == "oracle-top":
        return ref.oracle_top_attention(q, k, v, a.topk, key_mask=key_mask)
    raise ValueError(f"unknown attention kind {a.kind!r}")


def multi_head_attention(cfg: ModelConfig, p: dict, prefix: str, x, key_mask,
                         rng):
    """(N, D) → (N, D) self-attention with H independent heads."""
    a = cfg.attention
    h, dh = cfg.n_heads, cfg.d_head
    wq, wk = p[prefix + "attn/wq"], p[prefix + "attn/wk"]
    wv, wo = p[prefix + "attn/wv"], p[prefix + "attn/wo"]
    q = (x @ wq).reshape(-1, h, dh).transpose(1, 0, 2)      # (H, N, dh)
    if a.kind in ("shared-full", "lsh"):
        # shared-QK variants reuse the query projection (Reformer [13])
        k = q
    else:
        k = (x @ wk).reshape(-1, h, dh).transpose(1, 0, 2)
    v = (x @ wv).reshape(-1, h, dh).transpose(1, 0, 2)

    rngs = jax.random.split(rng, h)
    out = jax.vmap(
        lambda qi, ki, vi, ri: head_attention(a, qi, ki, vi, key_mask, ri)
    )(q, k, v, rngs)                                        # (H, N, dh)
    out = out.transpose(1, 0, 2).reshape(-1, h * dh)
    return out @ wo + p[prefix + "attn/bo"]


def encoder_layer(cfg: ModelConfig, p: dict, i: int, x, key_mask, rng):
    """Pre-LN transformer layer (stable to train without warmup)."""
    prefix = f"layer{i}/"
    h = layer_norm(x, p[prefix + "ln1/g"], p[prefix + "ln1/b"])
    x = x + multi_head_attention(cfg, p, prefix, h, key_mask, rng)
    h = layer_norm(x, p[prefix + "ln2/g"], p[prefix + "ln2/b"])
    h = jax.nn.gelu(h @ p[prefix + "ff1/w"] + p[prefix + "ff1/b"])
    return x + h @ p[prefix + "ff2/w"] + p[prefix + "ff2/b"]


def forward_single(cfg: ModelConfig, flat_params, x, key_mask, rng):
    """One sample: x is (N,) int tokens or (N, d_in) features."""
    p = unpack_params(cfg, flat_params)
    if cfg.vocab_in > 0:
        hdim = p["embed"][x.astype(jnp.int32)]              # (N, D)
    else:
        hdim = x @ p["in_proj/w"] + p["in_proj/b"]
    hdim = hdim * math.sqrt(cfg.d_model)
    hdim = hdim + p["pos_embed"]
    for i in range(cfg.n_layers):
        hdim = encoder_layer(cfg, p, i, hdim, key_mask,
                             jax.random.fold_in(rng, i))
    hdim = layer_norm(hdim, p["ln_f/g"], p["ln_f/b"])
    logits = hdim @ p["head/w"] + p["head/b"]               # (N, out)
    if cfg.task == "cls":
        denom = jnp.maximum(key_mask.sum(), 1.0)
        pooled = (logits * key_mask[:, None]).sum(0) / denom
        return pooled                                       # (out,)
    return logits


def forward(cfg: ModelConfig, flat_params, x, key_mask, seed):
    """Batched forward.  ``seed`` is a traced int32 scalar (clustering +
    reformer randomness); per-sample keys are folded from it."""
    base = jax.random.PRNGKey(seed)
    rngs = jax.random.split(base, x.shape[0])
    return jax.vmap(
        lambda xi, mi, ri: forward_single(cfg, flat_params, xi, mi, ri)
    )(x, key_mask, rngs)


def attention_maps(cfg: ModelConfig, flat_params, x, key_mask, seed,
                   layer: int, head: int):
    """Fig. 8 support: dense A (full), A^c-broadcast and A^t for one
    sample/layer/head, computed from the same activations."""
    p = unpack_params(cfg, flat_params)
    if cfg.vocab_in > 0:
        hdim = p["embed"][x.astype(jnp.int32)]
    else:
        hdim = x @ p["in_proj/w"] + p["in_proj/b"]
    hdim = hdim * math.sqrt(cfg.d_model) + p["pos_embed"]
    rng = jax.random.PRNGKey(seed)
    for i in range(layer):
        hdim = encoder_layer(cfg, p, i, hdim, key_mask,
                             jax.random.fold_in(rng, i))
    prefix = f"layer{layer}/"
    hn = layer_norm(hdim, p[prefix + "ln1/g"], p[prefix + "ln1/b"])
    h, dh = cfg.n_heads, cfg.d_head
    q = (hn @ p[prefix + "attn/wq"]).reshape(-1, h, dh)[:, head, :]
    k = (hn @ p[prefix + "attn/wk"]).reshape(-1, h, dh)[:, head, :]
    a = cfg.attention
    groups = ref.cluster_queries(q, a.clusters, a.bits, a.lloyd_iters,
                                 jax.random.fold_in(rng, layer),
                                 point_mask=key_mask)
    a_full = ref.full_attention_matrix(q, k, key_mask)
    a_c = ref.clustered_attention_matrix(q, k, groups, a.clusters,
                                         key_mask, key_mask)[groups]
    a_t = ref.improved_clustered_attention_matrix(
        q, k, groups, a.clusters, a.topk, key_mask, key_mask)
    return a_full, a_c, a_t
