"""Adam with decoupled weight decay + global-norm clipping, on the flat
parameter vector.  Mirrors the paper's training recipe (R-Adam, wd 0.01,
max grad-norm 10) closely enough for relative comparisons; the rectified
variance term of R-Adam matters only in the first dozen steps."""

from __future__ import annotations

import jax.numpy as jnp


def clip_by_global_norm(g, max_norm):
    norm = jnp.sqrt(jnp.maximum((g * g).sum(), 1e-12))
    scale = jnp.minimum(1.0, max_norm / norm)
    return g * scale


def adam_step(params, m, v, step, grads, *, lr, weight_decay=0.0,
              grad_clip=0.0, b1=0.9, b2=0.999, eps=1e-8):
    """One update.  All state is flat f32; ``step`` is int32 (0-based)."""
    if grad_clip > 0:
        grads = clip_by_global_norm(grads, grad_clip)
    step1 = step + 1
    m = b1 * m + (1 - b1) * grads
    v = b2 * v + (1 - b2) * grads * grads
    t = step1.astype(jnp.float32)
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + eps)
    if weight_decay > 0:
        upd = upd + weight_decay * params
    return params - lr * upd, m, v, step1
