"""Pallas kernels vs. the pure-jnp oracle (the core L1 correctness signal).

Shape/dtype sweeps are hypothesis-style: parametrised over a grid of
sequence lengths (including non-multiples of the block sizes), head dims,
cluster counts and seeds, asserting allclose against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels import pallas_kernels as pk

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def make_qkv(seed, n, dk, dv):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return rand(ks[0], n, dk), rand(ks[1], n, dk), rand(ks[2], n, dv)


def make_mask(seed, n, frac_valid=0.8):
    m = jnp.arange(n) < max(1, int(n * frac_valid))
    return m.astype(jnp.float32)


SHAPES = [  # (N, Dk, Dv) — includes non-block-multiples
    (16, 8, 8),
    (64, 32, 32),
    (100, 16, 24),
    (130, 32, 16),
    (256, 64, 64),
]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dk,dv", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_flash_attention_matches_ref(n, dk, dv, seed):
    q, k, v = make_qkv(seed, n, dk, dv)
    got = pk.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.full_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,dk,dv", [(64, 16, 16), (130, 32, 16)])
def test_flash_attention_with_mask(n, dk, dv):
    q, k, v = make_qkv(3, n, dk, dv)
    mask = make_mask(0, n)
    got = pk.flash_attention(q, k, v, key_mask=mask, block_q=32, block_k=32)
    want = ref.full_attention(q, k, v, key_mask=mask)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_rows_sum_to_one_property():
    # With V = I slices, attention output recovers the attention weights:
    # each row of A is a distribution.
    n, dk = 32, 8
    q, k, _ = make_qkv(7, n, dk, n)
    v = jnp.eye(n, dtype=jnp.float32)
    a = pk.flash_attention(q, k, v, block_q=16, block_k=16)
    np.testing.assert_allclose(a.sum(-1), np.ones(n), rtol=1e-5, atol=1e-5)
    assert (np.asarray(a) >= -1e-6).all()


# ---------------------------------------------------------------------------
# centroid sums (eq. 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c", [(16, 3), (100, 10), (130, 7), (256, 25)])
def test_centroid_sums_matches_ref(n, c):
    q = rand(jax.random.PRNGKey(0), n, 16)
    groups = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, c)
    sums, counts = pk.centroid_sums(q, groups, c, block_n=32)
    cent, want_counts = ref.cluster_centroids(q, groups, c)
    np.testing.assert_allclose(counts, want_counts, rtol=1e-6)
    got_cent = sums / np.maximum(np.asarray(counts), 1.0)[:, None]
    np.testing.assert_allclose(got_cent, cent, rtol=2e-5, atol=2e-5)


def test_centroid_sums_total_mass_property():
    # Sum of per-cluster sums == sum of all (unmasked) queries.
    n, c = 100, 9
    q = rand(jax.random.PRNGKey(2), n, 8)
    groups = jax.random.randint(jax.random.PRNGKey(3), (n,), 0, c)
    pm = make_mask(0, n, 0.7)
    sums, counts = pk.centroid_sums(q, groups, c, point_mask=pm, block_n=32)
    np.testing.assert_allclose(np.asarray(sums).sum(0),
                               np.asarray(q * pm[:, None]).sum(0),
                               rtol=1e-4, atol=1e-4)
    assert float(np.asarray(counts).sum()) == pytest.approx(float(pm.sum()))


# ---------------------------------------------------------------------------
# centroid attention (eqs. 4–5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,dk,dv", [(64, 8, 16, 16), (100, 25, 32, 24),
                                       (130, 10, 16, 16)])
def test_centroid_attention_matches_ref(n, c, dk, dv):
    q, k, v = make_qkv(5, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(6), (n,), 0, c)
    cent, _ = ref.cluster_centroids(q, groups, c)
    a_c, v_c = pk.centroid_attention(cent, k, v, block_c=8)
    scale = 1.0 / np.sqrt(dk)
    want_a = jax.nn.softmax(cent @ k.T * scale, axis=-1)
    np.testing.assert_allclose(a_c, want_a, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(v_c, want_a @ v, rtol=2e-5, atol=2e-5)


def test_centroid_attention_masked_columns_are_zero():
    n, c, dk, dv = 64, 8, 16, 16
    q, k, v = make_qkv(8, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, c)
    cent, _ = ref.cluster_centroids(q, groups, c)
    mask = make_mask(0, n, 0.5)
    a_c, _ = pk.centroid_attention(cent, k, v, key_mask=mask, block_c=8)
    a = np.asarray(a_c)
    assert np.abs(a[:, np.asarray(mask) == 0]).max() < 1e-8
    np.testing.assert_allclose(a.sum(-1), np.ones(c), rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end clustered attention (pallas pipeline vs ref)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,dk,dv", [(64, 8, 16, 16), (100, 25, 32, 24),
                                       (130, 10, 16, 16), (256, 25, 32, 32)])
@pytest.mark.parametrize("seed", [0, 4])
def test_clustered_attention_pallas_matches_ref(n, c, dk, dv, seed):
    q, k, v = make_qkv(seed, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, c)
    got = pk.clustered_attention_pallas(q, k, v, groups, c)
    want = ref.clustered_attention(q, k, v, groups, c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,c,dk,dv,t", [(64, 8, 16, 16, 8),
                                         (100, 25, 32, 24, 16),
                                         (130, 10, 16, 16, 32)])
@pytest.mark.parametrize("seed", [0, 4])
def test_improved_clustered_pallas_matches_ref(n, c, dk, dv, t, seed):
    q, k, v = make_qkv(seed, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    got = pk.improved_clustered_attention_pallas(q, k, v, groups, c, t)
    want = ref.improved_clustered_attention(q, k, v, groups, c, t)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_clustered_attention_masked():
    n, c, dk, dv = 100, 10, 16, 16
    q, k, v = make_qkv(11, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(12), (n,), 0, c)
    km = make_mask(0, n, 0.6)
    got = pk.clustered_attention_pallas(q, k, v, groups, c,
                                        key_mask=km, point_mask=km)
    want = ref.clustered_attention(q, k, v, groups, c,
                                   key_mask=km, point_mask=km)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_improved_clustered_masked():
    n, c, dk, dv, t = 100, 10, 16, 16, 8
    q, k, v = make_qkv(13, n, dk, dv)
    groups = jax.random.randint(jax.random.PRNGKey(14), (n,), 0, c)
    km = make_mask(0, n, 0.6)
    got = pk.improved_clustered_attention_pallas(q, k, v, groups, c, t,
                                                 key_mask=km, point_mask=km)
    want = ref.improved_clustered_attention(q, k, v, groups, c, t,
                                            key_mask=km, point_mask=km)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# hamming k-means
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bits,c", [(64, 16, 4), (200, 32, 10),
                                      (130, 63, 7)])
def test_hamming_assign_matches_argmax(n, bits, c):
    codes = jnp.sign(rand(jax.random.PRNGKey(0), n, bits)) + 0.0
    codes = jnp.where(codes == 0, 1.0, codes)
    cent = jnp.sign(rand(jax.random.PRNGKey(1), c, bits))
    got = pk.hamming_assign(codes, cent, block_n=32)
    want = jnp.argmax(codes @ cent.T, axis=-1)
    np.testing.assert_array_equal(got, want)


def test_hamming_assign_is_nearest_property():
    # Property: chosen centroid has minimal true Hamming distance.
    n, bits, c = 100, 32, 8
    codes = np.sign(np.random.RandomState(0).randn(n, bits)).astype(np.float32)
    cent = np.sign(np.random.RandomState(1).randn(c, bits)).astype(np.float32)
    g = np.asarray(pk.hamming_assign(jnp.asarray(codes), jnp.asarray(cent)))
    ham = ((codes[:, None, :] != cent[None, :, :]).sum(-1))  # (n, c)
    assert (ham[np.arange(n), g] == ham.min(axis=1)).all()


@pytest.mark.parametrize("n,bits,c,iters", [(128, 32, 8, 5), (200, 63, 10, 10)])
def test_hamming_kmeans_pallas_matches_ref(n, bits, c, iters):
    codes = jnp.where(rand(jax.random.PRNGKey(3), n, bits) >= 0, 1.0, -1.0)
    got = pk.hamming_kmeans_pallas(codes, c, iters)
    want = ref.hamming_kmeans(codes, c, iters)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# paper propositions on the reference implementation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_prop2_improved_never_worse_than_clustered(seed):
    """Proposition 2: ||A^t_i - A_i||_1 <= ||A^c_i - A_i||_1 for every i."""
    n, c, dk, t = 48, 6, 16, 8
    q, k, _ = make_qkv(seed, n, dk, dk)
    groups = jax.random.randint(jax.random.PRNGKey(seed + 40), (n,), 0, c)
    a = np.asarray(ref.full_attention_matrix(q, k))
    a_c = np.asarray(ref.clustered_attention_matrix(q, k, groups, c))[
        np.asarray(groups)]
    a_t = np.asarray(ref.improved_clustered_attention_matrix(
        q, k, groups, c, t))
    err_c = np.abs(a_c - a).sum(-1)
    err_t = np.abs(a_t - a).sum(-1)
    assert (err_t <= err_c + 1e-5).all()


@pytest.mark.parametrize("seed", range(3))
def test_prop1_attention_lipschitz_bound(seed):
    """Proposition 1: ||sm(QiK^T)-sm(QjK^T)||_2 <= ||Qi-Qj||_2 ||K||_2."""
    n, dk = 32, 16
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    k = rand(k1, n, dk)
    qi = rand(k2, dk)
    qj = qi + 0.1 * rand(k3, dk)
    # note: the bound is for unscaled logits as stated in the paper
    ai = jax.nn.softmax(k @ qi)
    aj = jax.nn.softmax(k @ qj)
    lhs = float(jnp.linalg.norm(ai - aj))
    knorm = float(jnp.linalg.norm(k, ord=2))
    eps = float(jnp.linalg.norm(qi - qj))
    assert lhs <= eps * knorm + 1e-5


def test_improved_matrix_rows_are_distributions():
    n, c, dk, t = 64, 8, 16, 8
    q, k, _ = make_qkv(21, n, dk, dk)
    groups = jax.random.randint(jax.random.PRNGKey(22), (n,), 0, c)
    a_t = np.asarray(ref.improved_clustered_attention_matrix(
        q, k, groups, c, t))
    assert (a_t >= -1e-7).all()
    np.testing.assert_allclose(a_t.sum(-1), np.ones(n), rtol=1e-4, atol=1e-4)


def test_clustered_exact_when_clusters_equal_queries():
    """C == N and singleton clusters ⇒ clustered attention is exact."""
    n, dk = 24, 8
    q, k, v = make_qkv(30, n, dk, dk)
    groups = jnp.arange(n)
    got = ref.clustered_attention(q, k, v, groups, n)
    want = ref.full_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_oracle_top_full_k_equals_full():
    n, dk = 32, 8
    q, k, v = make_qkv(31, n, dk, dk)
    got = ref.oracle_top_attention(q, k, v, topk=n)
    want = ref.full_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_reformer_runs_and_is_distribution_weighted():
    n, dk = 64, 16
    x, _, v = make_qkv(33, n, dk, dk)
    out = ref.reformer_attention(x, v, rounds=2, chunk=16,
                                 key=jax.random.PRNGKey(0))
    assert out.shape == (n, dk)
    assert np.isfinite(np.asarray(out)).all()


def test_kmeans_groups_in_range_and_deterministic():
    codes = jnp.where(rand(jax.random.PRNGKey(50), 200, 32) >= 0, 1.0, -1.0)
    g1 = ref.hamming_kmeans(codes, 16, 10)
    g2 = ref.hamming_kmeans(codes, 16, 10)
    np.testing.assert_array_equal(g1, g2)
    assert int(jnp.min(g1)) >= 0 and int(jnp.max(g1)) < 16
