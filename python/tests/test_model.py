"""L2 tests: flat-param layout, forward shapes for every attention variant
and task head, losses (CTC vs. brute force), optimizer, and a tiny
overfit run proving gradients flow through clustered attention."""

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, model, optim, programs
from compile.configs import AttentionConfig, ModelConfig


def tiny_cfg(kind="full", task="tok", **kw):
    a = AttentionConfig(kind=kind, clusters=4, topk=4, bits=15,
                        lloyd_iters=3, rounds=2, chunk=8)
    defaults = dict(name="tiny", task=task, attention=a, n_layers=2,
                    n_heads=2, d_head=8, d_ff=32, n_symbols=8, vocab_in=12,
                    seq_len=32, batch_size=2, max_labels=6)
    defaults.update(kw)
    return ModelConfig(**defaults)


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def test_param_spec_offsets_cover_vector_exactly():
    cfg = tiny_cfg()
    spec = model.param_spec(cfg)
    total = sum(int(math.prod(s)) for _, s in spec)
    assert total == model.param_count(cfg)
    flat = model.init_params(cfg, 0)
    assert flat.shape == (total,)


def test_unpack_params_roundtrip():
    cfg = tiny_cfg()
    flat = jnp.arange(model.param_count(cfg), dtype=jnp.float32)
    p = model.unpack_params(cfg, flat)
    # Re-concatenate in spec order and compare
    rebuilt = jnp.concatenate([p[n].reshape(-1)
                               for n, _ in model.param_spec(cfg)])
    np.testing.assert_array_equal(rebuilt, flat)


def test_param_layout_identical_across_variants():
    """Table 1 / Table 4 rely on checkpoint transfer between variants."""
    specs = [model.param_spec(tiny_cfg(kind=k))
             for k in ("full", "clustered", "i-clustered", "lsh")]
    assert all(s == specs[0] for s in specs)


def test_init_deterministic():
    cfg = tiny_cfg()
    np.testing.assert_array_equal(model.init_params(cfg, 7),
                                  model.init_params(cfg, 7))
    assert not np.array_equal(model.init_params(cfg, 7),
                              model.init_params(cfg, 8))


# ---------------------------------------------------------------------------
# forward shapes: every (variant, task) combination
# ---------------------------------------------------------------------------

VARIANTS = ["full", "shared-full", "clustered", "i-clustered", "lsh",
            "oracle-top"]


@pytest.mark.parametrize("kind", VARIANTS)
def test_forward_tok_shapes(kind):
    cfg = tiny_cfg(kind=kind)
    params = model.init_params(cfg, 0)
    x = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    mask = jnp.ones((cfg.batch_size, cfg.seq_len), jnp.float32)
    out = model.forward(cfg, params, x, mask, 0)
    assert out.shape == (cfg.batch_size, cfg.seq_len, cfg.n_symbols)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("task,kind", [("ctc", "full"), ("ctc", "i-clustered"),
                                       ("cls", "clustered"), ("span", "lsh")])
def test_forward_other_tasks(task, kind):
    kw = {}
    if task == "ctc":
        kw = dict(vocab_in=0, d_in=8)
    cfg = tiny_cfg(kind=kind, task=task, **kw)
    params = model.init_params(cfg, 0)
    b, n = cfg.batch_size, cfg.seq_len
    if task == "ctc":
        x = jax.random.normal(jax.random.PRNGKey(0), (b, n, 8))
    else:
        x = jnp.zeros((b, n), jnp.int32)
    mask = jnp.ones((b, n), jnp.float32)
    out = model.forward(cfg, params, x, mask, 0)
    if task == "cls":
        assert out.shape == (b, cfg.n_symbols)
    elif task == "span":
        assert out.shape == (b, n, 2)
    else:
        assert out.shape == (b, n, cfg.n_symbols + 1)
    assert np.isfinite(np.asarray(out)).all()


def test_forward_pallas_path_matches_ref_path():
    cfg_ref = tiny_cfg(kind="i-clustered")
    cfg_pal = tiny_cfg(kind="i-clustered")
    cfg_pal = ModelConfig(**{**cfg_pal.to_json_dict_clean(),
                             "attention": AttentionConfig(
                                 kind="i-clustered", clusters=4, topk=4,
                                 bits=15, lloyd_iters=3, use_pallas=True)}) \
        if hasattr(cfg_pal, "to_json_dict_clean") else None
    # simpler: construct directly
    a = AttentionConfig(kind="i-clustered", clusters=4, topk=4, bits=15,
                        lloyd_iters=3, use_pallas=True)
    cfg_pal = ModelConfig(name="tiny", task="tok", attention=a, n_layers=2,
                          n_heads=2, d_head=8, d_ff=32, n_symbols=8,
                          vocab_in=12, seq_len=32, batch_size=2)
    params = model.init_params(cfg_ref, 0)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 12)
    mask = jnp.ones((2, 32), jnp.float32)
    out_ref = model.forward(cfg_ref, params, x, mask, 5)
    out_pal = model.forward(cfg_pal, params, x, mask, 5)
    np.testing.assert_allclose(out_ref, out_pal, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# CTC loss
# ---------------------------------------------------------------------------

def brute_force_ctc(logp, labels):
    """Enumerate all alignments (tiny T only)."""
    t_len, vocab = logp.shape

    def collapse(path):
        out, prev = [], -1
        for s in path:
            if s != prev and s != 0:
                out.append(s)
            prev = s
        return tuple(out)

    total = -np.inf
    for path in itertools.product(range(vocab), repeat=t_len):
        if collapse(path) == tuple(labels):
            ll = sum(logp[t, s] for t, s in enumerate(path))
            total = np.logaddexp(total, ll)
    return -total


@pytest.mark.parametrize("seed", range(3))
def test_ctc_matches_brute_force(seed):
    t_len, vocab = 5, 4
    rng = np.random.RandomState(seed)
    logits = rng.randn(t_len, vocab).astype(np.float32)
    logp = np.asarray(losses.log_softmax(jnp.asarray(logits)))
    labels = np.array([1, 2], np.int32)
    want = brute_force_ctc(logp, labels)
    got = losses.ctc_loss_single(jnp.asarray(logits),
                                 jnp.asarray(t_len, jnp.int32),
                                 jnp.asarray(np.pad(labels, (0, 2))),
                                 jnp.asarray(2, jnp.int32))
    assert float(got) == pytest.approx(want, rel=1e-4)


def test_ctc_respects_input_len():
    """Padding frames beyond input_len must not change the loss."""
    t_len, vocab = 6, 4
    rng = np.random.RandomState(0)
    logits = rng.randn(t_len, vocab).astype(np.float32)
    labels = jnp.asarray([1, 3, 0, 0], jnp.int32)
    base = losses.ctc_loss_single(jnp.asarray(logits),
                                  jnp.asarray(4, jnp.int32), labels,
                                  jnp.asarray(2, jnp.int32))
    logits2 = logits.copy()
    logits2[4:] = 123.0  # garbage in padding
    got = losses.ctc_loss_single(jnp.asarray(logits2),
                                 jnp.asarray(4, jnp.int32), labels,
                                 jnp.asarray(2, jnp.int32))
    assert float(got) == pytest.approx(float(base), rel=1e-5)


def test_ctc_impossible_label_longer_than_input():
    logits = jnp.zeros((2, 4))
    loss = losses.ctc_loss_single(logits, jnp.asarray(2, jnp.int32),
                                  jnp.asarray([1, 1, 1], jnp.int32),
                                  jnp.asarray(3, jnp.int32))
    assert float(loss) > 1e6  # -LOG_EPS scale ⇒ effectively impossible


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adam_step_matches_manual():
    p = jnp.asarray([1.0, -2.0])
    g = jnp.asarray([0.5, 0.1])
    m = jnp.zeros(2)
    v = jnp.zeros(2)
    p2, m2, v2, s2 = optim.adam_step(p, m, v, jnp.asarray(0, jnp.int32), g,
                                     lr=0.1)
    mm = 0.1 * np.asarray(g)
    vv = 0.001 * np.asarray(g) ** 2
    mhat = mm / (1 - 0.9)
    vhat = vv / (1 - 0.999)
    want = np.asarray(p) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p2, want, rtol=1e-6)
    assert int(s2) == 1


def test_grad_clip():
    g = jnp.asarray([30.0, 40.0])  # norm 50
    clipped = optim.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(clipped), [6.0, 8.0], rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end train steps (gradients flow through every variant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["full", "clustered", "i-clustered", "lsh"])
def test_train_step_decreases_loss(kind):
    cfg = tiny_cfg(kind=kind, lr=3e-3)
    fn, specs, names, outs = programs.make_train_step(cfg)
    fn = jax.jit(fn)
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (cfg.batch_size, cfg.seq_len), 0, 12)
    y = jnp.asarray(x % cfg.n_symbols, jnp.int32)  # learnable identity-ish
    w = jnp.ones_like(x, jnp.float32)
    params = model.init_params(cfg, 0)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.asarray(0, jnp.int32)
    first = None
    for i in range(12):
        params, m, v, step, loss = fn(params, m, v, step,
                                      jnp.asarray(i, jnp.int32), x, y, w)
        if first is None:
            first = float(loss)
    assert np.isfinite(float(loss))
    assert float(loss) < first * 0.8, (first, float(loss))


def test_eval_loss_program_runs():
    cfg = tiny_cfg(kind="clustered")
    fn, specs, names, outs = programs.make_eval_loss(cfg)
    args = [jnp.zeros(s.shape, s.dtype) if s.dtype == jnp.int32
            else jnp.ones(s.shape, s.dtype) for s in specs]
    args[0] = model.init_params(cfg, 0)
    (loss,) = jax.jit(fn)(*args)
    assert np.isfinite(float(loss))


def test_attention_maps_program():
    cfg = tiny_cfg(kind="i-clustered")
    fn, specs, names, outs = programs.make_attention_maps(cfg, layer=1,
                                                          head=0)
    params = model.init_params(cfg, 0)
    x = jax.random.randint(jax.random.PRNGKey(0), (cfg.seq_len,), 0, 12)
    mask = jnp.ones((cfg.seq_len,), jnp.float32)
    a, ac, at = jax.jit(fn)(params, x, mask, jnp.asarray(0, jnp.int32))
    n = cfg.seq_len
    assert a.shape == (n, n) and ac.shape == (n, n) and at.shape == (n, n)
    # all three are row-stochastic
    for mat in (a, ac, at):
        np.testing.assert_allclose(np.asarray(mat).sum(-1), np.ones(n),
                                   rtol=1e-3, atol=1e-3)
    # Prop 2 on real activations: i-clustered at least as close to full
    ea = np.abs(np.asarray(ac) - np.asarray(a)).sum(-1)
    et = np.abs(np.asarray(at) - np.asarray(a)).sum(-1)
    assert (et <= ea + 1e-4).all()
